//! Adam trainer for the float MLP0 (scikit-learn `MLPClassifier` stand-in)
//! plus the shared softmax/cross-entropy math reused by the pure-Rust
//! retraining backend.

use super::Mlp;
use crate::util::rng::Rng;

/// Softmax in place; numerically stabilized.
pub fn softmax(logits: &mut [f32]) {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in logits.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    for v in logits.iter_mut() {
        *v /= sum;
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub batch: usize,
    pub lr: f32,
    pub weight_decay: f32,
    pub seed: u64,
    /// Stop early when train accuracy exceeds this (0 disables).
    pub target_train_acc: f64,
    /// Plateau patience: stop when train accuracy hasn't improved for
    /// this many epochs (0 disables). Accuracy is checked every epoch
    /// when either stopping rule is active.
    pub patience: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 300,
            batch: 32,
            lr: 3e-3,
            weight_decay: 1e-5,
            seed: 0xC0FFEE,
            target_train_acc: 0.0,
            patience: 30,
        }
    }
}

struct Adam {
    m: Vec<f32>,
    v: Vec<f32>,
    t: i32,
    beta1: f32,
    beta2: f32,
    eps: f32,
}

impl Adam {
    fn new(n: usize) -> Self {
        Adam {
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }

    fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t);
        let b2t = 1.0 - self.beta2.powi(self.t);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grads[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grads[i] * grads[i];
            let mh = self.m[i] / b1t;
            let vh = self.v[i] / b2t;
            params[i] -= lr * mh / (vh.sqrt() + self.eps);
        }
    }
}

fn flatten(m: &Mlp) -> Vec<f32> {
    let mut p = Vec::new();
    for r in &m.w1 {
        p.extend_from_slice(r);
    }
    p.extend_from_slice(&m.b1);
    for r in &m.w2 {
        p.extend_from_slice(r);
    }
    p.extend_from_slice(&m.b2);
    p
}

fn unflatten(m: &mut Mlp, p: &[f32]) {
    let mut i = 0;
    for r in m.w1.iter_mut() {
        let n = r.len();
        r.copy_from_slice(&p[i..i + n]);
        i += n;
    }
    let n = m.b1.len();
    m.b1.copy_from_slice(&p[i..i + n]);
    i += n;
    for r in m.w2.iter_mut() {
        let n = r.len();
        r.copy_from_slice(&p[i..i + n]);
        i += n;
    }
    let n = m.b2.len();
    m.b2.copy_from_slice(&p[i..i + n]);
}

/// Mean CE loss + parameter gradient over a batch (backprop).
pub fn loss_and_grad(m: &Mlp, xs: &[&Vec<f32>], ys: &[usize]) -> (f32, Vec<f32>) {
    let n = xs.len();
    let mut gw1 = vec![vec![0.0f32; m.din]; m.hidden];
    let mut gb1 = vec![0.0f32; m.hidden];
    let mut gw2 = vec![vec![0.0f32; m.hidden]; m.dout];
    let mut gb2 = vec![0.0f32; m.dout];
    let mut loss = 0.0f32;

    for (x, &y) in xs.iter().zip(ys) {
        // forward
        let mut z1 = vec![0.0f32; m.hidden];
        for j in 0..m.hidden {
            z1[j] = m.w1[j].iter().zip(x.iter()).map(|(&w, &v)| w * v).sum::<f32>() + m.b1[j];
        }
        let h: Vec<f32> = z1.iter().map(|&v| v.max(0.0)).collect();
        let mut logits = vec![0.0f32; m.dout];
        for o in 0..m.dout {
            logits[o] =
                m.w2[o].iter().zip(&h).map(|(&w, &v)| w * v).sum::<f32>() + m.b2[o];
        }
        let mut p = logits.clone();
        softmax(&mut p);
        loss += -(p[y].max(1e-12)).ln();
        // backward
        let mut dlogits = p;
        dlogits[y] -= 1.0;
        for o in 0..m.dout {
            gb2[o] += dlogits[o];
            for j in 0..m.hidden {
                gw2[o][j] += dlogits[o] * h[j];
            }
        }
        for j in 0..m.hidden {
            if z1[j] <= 0.0 {
                continue;
            }
            let dh: f32 = (0..m.dout).map(|o| dlogits[o] * m.w2[o][j]).sum();
            gb1[j] += dh;
            for i in 0..m.din {
                gw1[j][i] += dh * x[i];
            }
        }
    }

    let scale = 1.0 / n as f32;
    let mut g = Vec::new();
    for r in &gw1 {
        g.extend(r.iter().map(|v| v * scale));
    }
    g.extend(gb1.iter().map(|v| v * scale));
    for r in &gw2 {
        g.extend(r.iter().map(|v| v * scale));
    }
    g.extend(gb2.iter().map(|v| v * scale));
    (loss * scale, g)
}

/// Train (in place); returns the final train accuracy.
pub fn train(m: &mut Mlp, xs: &[Vec<f32>], ys: &[usize], cfg: &TrainConfig) -> f64 {
    let mut rng = Rng::new(cfg.seed);
    let mut params = flatten(m);
    let mut adam = Adam::new(params.len());
    let n = xs.len();
    let mut best_acc = 0.0f64;
    let mut stale = 0usize;
    for _epoch in 0..cfg.epochs {
        let perm = rng.permutation(n);
        for chunk in perm.chunks(cfg.batch) {
            let bx: Vec<&Vec<f32>> = chunk.iter().map(|&i| &xs[i]).collect();
            let by: Vec<usize> = chunk.iter().map(|&i| ys[i]).collect();
            unflatten(m, &params);
            let (_l, mut g) = loss_and_grad(m, &bx, &by);
            if cfg.weight_decay > 0.0 {
                for (gi, pi) in g.iter_mut().zip(&params) {
                    *gi += cfg.weight_decay * pi;
                }
            }
            adam.step(&mut params, &g, cfg.lr);
        }
        unflatten(m, &params);
        if cfg.target_train_acc > 0.0 || cfg.patience > 0 {
            let acc = m.accuracy(xs, ys);
            if cfg.target_train_acc > 0.0 && acc >= cfg.target_train_acc {
                break;
            }
            if acc > best_acc + 1e-3 {
                best_acc = acc;
                stale = 0;
            } else {
                stale += 1;
                if cfg.patience > 0 && stale >= cfg.patience {
                    break;
                }
            }
        }
    }
    unflatten(m, &params);
    m.accuracy(xs, ys)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob_problem(rng: &mut Rng, n: usize) -> (Vec<Vec<f32>>, Vec<usize>) {
        // 3 well-separated Gaussian blobs in 2D, normalized to [0,1]
        let centers = [(0.2f64, 0.2f64), (0.8, 0.2), (0.5, 0.85)];
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..n {
            let c = i % 3;
            let (cx, cy) = centers[c];
            xs.push(vec![
                (rng.gauss(cx, 0.07)).clamp(0.0, 1.0) as f32,
                (rng.gauss(cy, 0.07)).clamp(0.0, 1.0) as f32,
            ]);
            ys.push(c);
        }
        (xs, ys)
    }

    #[test]
    fn trains_blobs_to_high_accuracy() {
        let mut rng = Rng::new(9);
        let (xs, ys) = blob_problem(&mut rng, 300);
        let mut m = Mlp::new_random(2, 4, 3, &mut rng);
        let cfg = TrainConfig {
            epochs: 120,
            target_train_acc: 0.97,
            ..Default::default()
        };
        let acc = train(&mut m, &xs, &ys, &cfg);
        assert!(acc > 0.95, "train acc {acc}");
    }

    #[test]
    fn gradient_check_numerical() {
        let mut rng = Rng::new(10);
        let mut m = Mlp::new_random(3, 2, 2, &mut rng);
        let x = vec![0.3f32, 0.8, 0.1];
        let xs = vec![&x];
        let ys = vec![1usize];
        let (_, g) = loss_and_grad(&m, &xs, &ys);
        // perturb w1[0][1]
        let eps = 1e-3f32;
        let orig = m.w1[0][1];
        m.w1[0][1] = orig + eps;
        let (lp, _) = loss_and_grad(&m, &xs, &ys);
        m.w1[0][1] = orig - eps;
        let (lm, _) = loss_and_grad(&m, &xs, &ys);
        m.w1[0][1] = orig;
        let numeric = (lp - lm) / (2.0 * eps);
        let analytic = g[1]; // w1 row 0, col 1
        assert!(
            (numeric - analytic).abs() < 1e-2,
            "numeric {numeric} vs analytic {analytic}"
        );
    }

    #[test]
    fn softmax_normalizes() {
        let mut v = vec![1.0f32, 2.0, 3.0];
        softmax(&mut v);
        let s: f32 = v.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(v[2] > v[1] && v[1] > v[0]);
    }
}

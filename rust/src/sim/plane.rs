//! Plane words: the machine word a bit-plane is stored in.
//!
//! The bit-sliced engines (`sim::simulate_packed`, `axsum::bitslice`)
//! store every value as *bit-planes*: plane `b` is a word whose bit `p`
//! is bit `b` of the value for stimulus pattern `p`. Historically that
//! word was hard-wired to `u64` (64 patterns per pass). [`PlaneWord`]
//! abstracts the word so one ripple/carry-save pass can advance
//!
//!  * 64 patterns (`u64` — the baseline),
//!  * 128 patterns (`u128` — two ALU ops per plane op on 64-bit
//!    targets, but half the loop/bookkeeping overhead), or
//!  * 256+ patterns ([`Lanes4`] — a portable-SIMD-shaped `[u64; N]`
//!    newtype whose per-lane loops LLVM auto-vectorizes to SSE2/AVX2
//!    vector ops; no nightly `std::simd` or extra dependency needed).
//!
//! The [`PackedStimulus`](crate::sim::PackedStimulus) transpose stays
//! `u64`-grained on disk/in memory; [`PackedStimulus::feature_word`]
//! gathers `W::PATTERNS / 64` consecutive 64-pattern sub-chunks into one
//! wide plane word, so every width reads the *same* shared transpose and
//! the engines stay bit-identical across widths by construction.
//!
//! ```
//! use axmlp::sim::plane::{Lanes4, PlaneWord};
//!
//! // pattern capacity per plane word
//! assert_eq!(<u64 as PlaneWord>::PATTERNS, 64);
//! assert_eq!(<u128 as PlaneWord>::PATTERNS, 128);
//! assert_eq!(<Lanes4 as PlaneWord>::PATTERNS, 256);
//!
//! // a plane word is just a bag of per-pattern bits
//! let mut w = <u128 as PlaneWord>::ZERO;
//! w.set_bit(70);
//! assert!(w.bit(70) && !w.bit(71));
//! assert_eq!(w.count_ones(), 1);
//!
//! // word-level boolean algebra is what makes one op = W::PATTERNS
//! // forward passes: here, a 256-wide full-adder sum plane
//! let (a, b, c) = (Lanes4::ONES, Lanes4::ZERO, Lanes4::ONES);
//! let sum = a.xor(b).xor(c);
//! assert_eq!(sum, Lanes4::ZERO);
//! ```

use crate::sim::PackedStimulus;

/// One plane word: `PATTERNS` stimulus patterns advanced per bitwise op.
///
/// Implementations are thin wrappers over word-level boolean algebra —
/// everything the bit-sliced AxSum engine needs (ripple and carry-save
/// adders, sign masks, compare-select tournaments, popcount scoring)
/// is expressible in these ten operations. See the [module
/// docs](self) for a worked example and the width trade-offs.
pub trait PlaneWord: Copy + PartialEq + Eq + std::fmt::Debug + Send + Sync + 'static {
    /// Stimulus patterns carried per word (always a multiple of 64).
    const PATTERNS: usize;
    /// All pattern bits clear.
    const ZERO: Self;
    /// All pattern bits set.
    const ONES: Self;

    fn not(self) -> Self;
    fn and(self, o: Self) -> Self;
    fn or(self, o: Self) -> Self;
    fn xor(self, o: Self) -> Self;
    fn is_zero(self) -> bool;
    fn count_ones(self) -> u32;
    /// Bit of pattern `p` (`p < PATTERNS`).
    fn bit(self, p: usize) -> bool;
    /// Set the bit of pattern `p` (`p < PATTERNS`).
    fn set_bit(&mut self, p: usize);
    /// Word with the low `n` pattern bits set (`n <= PATTERNS`) — the
    /// tail mask for a partial final chunk.
    fn mask_low(n: usize) -> Self;
    /// Assemble a wide word from its 64-pattern sub-words: `f(s)` must
    /// return the `u64` carrying patterns `64*s .. 64*(s+1)`.
    fn gather(f: impl FnMut(usize) -> u64) -> Self;
}

impl PlaneWord for u64 {
    const PATTERNS: usize = 64;
    const ZERO: Self = 0;
    const ONES: Self = u64::MAX;

    #[inline(always)]
    fn not(self) -> Self {
        !self
    }
    #[inline(always)]
    fn and(self, o: Self) -> Self {
        self & o
    }
    #[inline(always)]
    fn or(self, o: Self) -> Self {
        self | o
    }
    #[inline(always)]
    fn xor(self, o: Self) -> Self {
        self ^ o
    }
    #[inline(always)]
    fn is_zero(self) -> bool {
        self == 0
    }
    #[inline(always)]
    fn count_ones(self) -> u32 {
        u64::count_ones(self)
    }
    #[inline(always)]
    fn bit(self, p: usize) -> bool {
        (self >> p) & 1 == 1
    }
    #[inline(always)]
    fn set_bit(&mut self, p: usize) {
        *self |= 1u64 << p;
    }
    #[inline(always)]
    fn mask_low(n: usize) -> Self {
        if n >= 64 {
            u64::MAX
        } else {
            (1u64 << n) - 1
        }
    }
    #[inline(always)]
    fn gather(mut f: impl FnMut(usize) -> u64) -> Self {
        f(0)
    }
}

impl PlaneWord for u128 {
    const PATTERNS: usize = 128;
    const ZERO: Self = 0;
    const ONES: Self = u128::MAX;

    #[inline(always)]
    fn not(self) -> Self {
        !self
    }
    #[inline(always)]
    fn and(self, o: Self) -> Self {
        self & o
    }
    #[inline(always)]
    fn or(self, o: Self) -> Self {
        self | o
    }
    #[inline(always)]
    fn xor(self, o: Self) -> Self {
        self ^ o
    }
    #[inline(always)]
    fn is_zero(self) -> bool {
        self == 0
    }
    #[inline(always)]
    fn count_ones(self) -> u32 {
        u128::count_ones(self)
    }
    #[inline(always)]
    fn bit(self, p: usize) -> bool {
        (self >> p) & 1 == 1
    }
    #[inline(always)]
    fn set_bit(&mut self, p: usize) {
        *self |= 1u128 << p;
    }
    #[inline(always)]
    fn mask_low(n: usize) -> Self {
        if n >= 128 {
            u128::MAX
        } else {
            (1u128 << n) - 1
        }
    }
    #[inline(always)]
    fn gather(mut f: impl FnMut(usize) -> u64) -> Self {
        (f(0) as u128) | ((f(1) as u128) << 64)
    }
}

/// Portable-SIMD-shaped plane word: `N` independent `u64` lanes, so all
/// per-lane loops are trivially vectorizable (`std::simd` is nightly-only
/// and the vendor set is frozen, so this relies on LLVM's auto-vectorizer
/// — the 32-byte alignment keeps `Lanes<4>` one AVX2 register).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(align(32))]
pub struct Lanes<const N: usize>(pub [u64; N]);

/// 256 patterns per plane word (one AVX2 register per plane op).
pub type Lanes4 = Lanes<4>;

impl<const N: usize> PlaneWord for Lanes<N> {
    const PATTERNS: usize = 64 * N;
    const ZERO: Self = Lanes([0u64; N]);
    const ONES: Self = Lanes([u64::MAX; N]);

    #[inline(always)]
    fn not(self) -> Self {
        let mut o = self.0;
        for v in o.iter_mut() {
            *v = !*v;
        }
        Lanes(o)
    }
    #[inline(always)]
    fn and(self, o: Self) -> Self {
        let mut r = self.0;
        for (v, w) in r.iter_mut().zip(o.0) {
            *v &= w;
        }
        Lanes(r)
    }
    #[inline(always)]
    fn or(self, o: Self) -> Self {
        let mut r = self.0;
        for (v, w) in r.iter_mut().zip(o.0) {
            *v |= w;
        }
        Lanes(r)
    }
    #[inline(always)]
    fn xor(self, o: Self) -> Self {
        let mut r = self.0;
        for (v, w) in r.iter_mut().zip(o.0) {
            *v ^= w;
        }
        Lanes(r)
    }
    #[inline(always)]
    fn is_zero(self) -> bool {
        self.0.iter().all(|&v| v == 0)
    }
    #[inline(always)]
    fn count_ones(self) -> u32 {
        self.0.iter().map(|v| v.count_ones()).sum()
    }
    #[inline(always)]
    fn bit(self, p: usize) -> bool {
        (self.0[p / 64] >> (p % 64)) & 1 == 1
    }
    #[inline(always)]
    fn set_bit(&mut self, p: usize) {
        self.0[p / 64] |= 1u64 << (p % 64);
    }
    #[inline(always)]
    fn mask_low(n: usize) -> Self {
        let mut r = [0u64; N];
        for (s, v) in r.iter_mut().enumerate() {
            let lo = s * 64;
            *v = if n >= lo + 64 {
                u64::MAX
            } else if n > lo {
                (1u64 << (n - lo)) - 1
            } else {
                0
            };
        }
        Lanes(r)
    }
    #[inline(always)]
    fn gather(mut f: impl FnMut(usize) -> u64) -> Self {
        Lanes(std::array::from_fn(&mut f))
    }
}

impl PackedStimulus {
    /// Wide-word view of the shared transpose: the plane word of feature
    /// bus `i`, bit lane `bit`, *wide* chunk `chunk` (each wide chunk
    /// covers `W::PATTERNS / 64` consecutive 64-pattern chunks of
    /// [`Self::feature_lane`]). Sub-chunks past the stimulus read 0, so
    /// tail patterns of a partial final wide chunk evaluate the all-zero
    /// stimulus and are masked out by the callers' tail handling —
    /// exactly the narrow engine's partial-chunk semantics, which is what
    /// keeps every plane width bit-identical.
    pub fn feature_word<W: PlaneWord>(&self, i: usize, bit: usize, chunk: usize) -> W {
        let subs = W::PATTERNS / 64;
        W::gather(|s| self.feature_lane(i, bit, chunk * subs + s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_word<W: PlaneWord>() {
        assert_eq!(W::PATTERNS % 64, 0);
        assert!(W::ZERO.is_zero() && !W::ONES.is_zero());
        assert_eq!(W::ONES.count_ones() as usize, W::PATTERNS);
        assert_eq!(W::ZERO.not(), W::ONES);
        assert_eq!(W::mask_low(0), W::ZERO);
        assert_eq!(W::mask_low(W::PATTERNS), W::ONES);
        for p in [0, 1, 63, W::PATTERNS / 2, W::PATTERNS - 1] {
            let mut w = W::ZERO;
            w.set_bit(p);
            assert!(w.bit(p), "pattern {p}");
            assert_eq!(w.count_ones(), 1);
            assert_eq!(w.and(W::ONES), w);
            assert_eq!(w.or(W::ZERO), w);
            assert_eq!(w.xor(w), W::ZERO);
            // mask_low(p) excludes pattern p, mask_low(p+1) includes it
            assert!(!w.and(W::mask_low(p)).bit(p));
            assert!(w.and(W::mask_low(p + 1)).bit(p));
        }
    }

    #[test]
    fn word_algebra_all_widths() {
        check_word::<u64>();
        check_word::<u128>();
        check_word::<Lanes<2>>();
        check_word::<Lanes4>();
    }

    #[test]
    fn gather_orders_subwords_low_to_high() {
        let w: u128 = PlaneWord::gather(|s| (s as u64) + 1);
        assert_eq!(w, 1u128 | (2u128 << 64));
        let l: Lanes4 = PlaneWord::gather(|s| s as u64);
        assert_eq!(l.0, [0, 1, 2, 3]);
        // pattern indexing agrees with the gather order
        let mut v: Lanes4 = PlaneWord::gather(|s| if s == 2 { 1 } else { 0 });
        assert!(v.bit(128) && !v.bit(64));
        v.set_bit(64);
        assert!(v.bit(64));
    }

    #[test]
    fn feature_word_matches_feature_lane() {
        let xs: Vec<Vec<i64>> = (0..200).map(|p| vec![(p % 16) as i64, 15]).collect();
        let stim = PackedStimulus::from_features(&xs, 2, 4).unwrap();
        for bit in 0..4 {
            for wide in 0..2 {
                let w: u128 = stim.feature_word(0, bit, wide);
                let l: Lanes4 = stim.feature_word(0, bit, wide);
                for sub in 0..2 {
                    let narrow = stim.feature_lane(0, bit, wide * 2 + sub);
                    assert_eq!((w >> (64 * sub)) as u64, narrow);
                }
                for sub in 0..4 {
                    assert_eq!(l.0[sub], stim.feature_lane(0, bit, wide * 4 + sub));
                }
            }
            // past the stimulus: zero, like feature_lane
            let tail: Lanes4 = stim.feature_word(0, bit, 9);
            assert_eq!(tail, Lanes4::ZERO);
        }
    }
}

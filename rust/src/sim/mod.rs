//! Levelized, word-parallel logic simulator (Questasim substitute).
//!
//! Because gates are stored in topological order, simulation is one
//! forward pass. Patterns are packed 64-per-u64 word, so a full test-set
//! stimulus of a few hundred vectors costs a handful of machine ops per
//! gate. The simulator doubles as:
//!
//!  * functional verifier — bit-exact against `axsum`'s integer model;
//!  * switching-activity source — per-gate toggle counts feed the dynamic
//!    power term in `estimate` (what PrimeTime does with Questasim VCDs).

use std::collections::HashMap;

use crate::netlist::Netlist;
use crate::pdk::CellKind;

/// Result of a simulation run.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Per output bus: one u64 value per pattern (LSB-first bus packing).
    pub outputs: HashMap<String, Vec<u64>>,
    /// Per-gate toggle counts across the pattern sequence (empty if
    /// toggle capture was off).
    pub toggles: Vec<u64>,
    pub patterns: usize,
}

/// Simulate `patterns` input vectors. `inputs` maps bus name -> per-pattern
/// unsigned values (LSB-first packing into the bus nets). Missing buses
/// default to all-zero. When `capture_toggles` is set, per-gate transition
/// counts over the pattern *sequence* are accumulated (stimulus order is
/// meaningful, as in a testbench).
pub fn simulate(
    nl: &Netlist,
    inputs: &HashMap<String, Vec<u64>>,
    patterns: usize,
    capture_toggles: bool,
) -> SimResult {
    let n = nl.gates.len();
    let mut toggles = if capture_toggles { vec![0u64; n] } else { Vec::new() };
    let mut outputs: HashMap<String, Vec<u64>> = nl
        .outputs
        .iter()
        .map(|b| (b.name.clone(), Vec::with_capacity(patterns)))
        .collect();

    let mut words = vec![0u64; n];
    // previous chunk's final pattern value per net (bit 0 = value)
    let mut prev_last = vec![0u64; n];
    let chunks = patterns.div_ceil(64);

    for chunk in 0..chunks {
        let base = chunk * 64;
        let in_chunk = (patterns - base).min(64);

        // load inputs
        for bus in &nl.inputs {
            let vals = inputs.get(&bus.name);
            for (biti, &net) in bus.nets.iter().enumerate() {
                let mut w = 0u64;
                for p in 0..in_chunk {
                    let v = vals.and_then(|v| v.get(base + p)).copied().unwrap_or(0);
                    if (v >> biti) & 1 == 1 {
                        w |= 1u64 << p;
                    }
                }
                words[net as usize] = w;
            }
        }

        // evaluate (+ fused toggle counting: one pass over the gate array
        // instead of two — see EXPERIMENTS.md §Perf)
        let mask = if in_chunk == 64 {
            u64::MAX
        } else {
            (1u64 << in_chunk) - 1
        };
        for (i, g) in nl.gates.iter().enumerate() {
            let w = match g.kind {
                CellKind::Input => words[i],
                CellKind::Const0 => 0,
                CellKind::Const1 => u64::MAX,
                CellKind::Buf => words[g.ins[0] as usize],
                CellKind::Inv => !words[g.ins[0] as usize],
                CellKind::And2 => words[g.ins[0] as usize] & words[g.ins[1] as usize],
                CellKind::Or2 => words[g.ins[0] as usize] | words[g.ins[1] as usize],
                CellKind::Nand2 => !(words[g.ins[0] as usize] & words[g.ins[1] as usize]),
                CellKind::Nor2 => !(words[g.ins[0] as usize] | words[g.ins[1] as usize]),
                CellKind::Xor2 => words[g.ins[0] as usize] ^ words[g.ins[1] as usize],
                CellKind::Xnor2 => !(words[g.ins[0] as usize] ^ words[g.ins[1] as usize]),
                CellKind::Mux2 => {
                    let s = words[g.ins[0] as usize];
                    (s & words[g.ins[1] as usize]) | (!s & words[g.ins[2] as usize])
                }
            };
            words[i] = w;
            if capture_toggles {
                let wm = w & mask;
                // transitions within the chunk: pattern p-1 -> p
                let within = (wm ^ (wm >> 1)) & (mask >> 1);
                let mut t = within.count_ones() as u64;
                // boundary transition from previous chunk's last pattern
                if chunk > 0 && (wm & 1) != prev_last[i] {
                    t += 1;
                }
                toggles[i] += t;
                prev_last[i] = (wm >> (in_chunk - 1)) & 1;
            }
        }

        // read outputs
        for bus in &nl.outputs {
            let dst = outputs.get_mut(&bus.name).unwrap();
            for p in 0..in_chunk {
                let mut v = 0u64;
                for (biti, &net) in bus.nets.iter().enumerate() {
                    if (words[net as usize] >> p) & 1 == 1 {
                        v |= 1u64 << biti;
                    }
                }
                dst.push(v);
            }
        }
    }

    SimResult {
        outputs,
        toggles,
        patterns,
    }
}

/// One-pattern convenience evaluator for tests: returns bus name -> value.
pub fn eval_once(nl: &Netlist, assignments: &[(&str, u64)]) -> HashMap<String, u64> {
    let inputs: HashMap<String, Vec<u64>> = assignments
        .iter()
        .map(|(n, v)| (n.to_string(), vec![*v]))
        .collect();
    let r = simulate(nl, &inputs, 1, false);
    r.outputs
        .into_iter()
        .map(|(k, mut v)| (k, v.pop().unwrap()))
        .collect()
}

/// Signed read helper: interpret a bus value of width `w` as two's
/// complement.
pub fn as_signed(v: u64, w: usize) -> i64 {
    if w == 0 || w >= 64 {
        return v as i64;
    }
    let m = 1u64 << (w - 1);
    (((v & ((1u64 << w) - 1)) ^ m) as i64) - m as i64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gates_truth_tables() {
        let mut nl = Netlist::new("t");
        let v = nl.input_bus("v", 2);
        let (a, b) = (v[0], v[1]);
        let and = nl.and(a, b);
        let or = nl.or(a, b);
        let xor = nl.xor(a, b);
        let na = nl.not(a);
        nl.output_bus("and", vec![and]);
        nl.output_bus("or", vec![or]);
        nl.output_bus("xor", vec![xor]);
        nl.output_bus("na", vec![na]);
        for v_in in 0..4u64 {
            let out = eval_once(&nl, &[("v", v_in)]);
            let (a, b) = (v_in & 1, (v_in >> 1) & 1);
            assert_eq!(out["and"], a & b);
            assert_eq!(out["or"], a | b);
            assert_eq!(out["xor"], a ^ b);
            assert_eq!(out["na"], 1 - a);
        }
    }

    #[test]
    fn mux_truth_table() {
        let mut nl = Netlist::new("t");
        let v = nl.input_bus("v", 3);
        let m = nl.mux(v[0], v[1], v[2]);
        nl.output_bus("m", vec![m]);
        for v_in in 0..8u64 {
            let out = eval_once(&nl, &[("v", v_in)]);
            let (s, a, b) = (v_in & 1, (v_in >> 1) & 1, (v_in >> 2) & 1);
            assert_eq!(out["m"], if s == 1 { a } else { b });
        }
    }

    #[test]
    fn multi_pattern_matches_single() {
        let mut nl = Netlist::new("t");
        let a = nl.input_bus("a", 4);
        let b = nl.input_bus("b", 4);
        let mut acc = Vec::new();
        for i in 0..4 {
            acc.push(nl.xor(a[i], b[i]));
        }
        nl.output_bus("y", acc);
        let mut rng = Rng::new(5);
        let pats = 200;
        let av: Vec<u64> = (0..pats).map(|_| rng.below(16) as u64).collect();
        let bv: Vec<u64> = (0..pats).map(|_| rng.below(16) as u64).collect();
        let mut inputs = HashMap::new();
        inputs.insert("a".to_string(), av.clone());
        inputs.insert("b".to_string(), bv.clone());
        let r = simulate(&nl, &inputs, pats, true);
        for p in 0..pats {
            let one = eval_once(&nl, &[("a", av[p]), ("b", bv[p])]);
            assert_eq!(r.outputs["y"][p], one["y"], "pattern {p}");
        }
    }

    #[test]
    fn toggle_counting_alternating() {
        // single inverter driven by alternating input: every pattern
        // transition toggles both nets.
        let mut nl = Netlist::new("t");
        let a = nl.input_bus("a", 1);
        let ia = nl.not(a[0]);
        nl.output_bus("y", vec![ia]);
        let pats = 130; // crosses two word boundaries
        let vals: Vec<u64> = (0..pats).map(|p| (p % 2) as u64).collect();
        let mut inputs = HashMap::new();
        inputs.insert("a".to_string(), vals);
        let r = simulate(&nl, &inputs, pats, true);
        // input net toggles pats-1 times; inverter follows
        let inv_idx = ia as usize;
        assert_eq!(r.toggles[inv_idx], (pats - 1) as u64);
    }

    #[test]
    fn toggle_counting_constant_input() {
        let mut nl = Netlist::new("t");
        let a = nl.input_bus("a", 1);
        let ia = nl.not(a[0]);
        nl.output_bus("y", vec![ia]);
        let vals: Vec<u64> = vec![1; 100];
        let mut inputs = HashMap::new();
        inputs.insert("a".to_string(), vals);
        let r = simulate(&nl, &inputs, 100, true);
        assert_eq!(r.toggles[ia as usize], 0);
    }

    #[test]
    fn as_signed_roundtrip() {
        assert_eq!(as_signed(0b111, 3), -1);
        assert_eq!(as_signed(0b011, 3), 3);
        assert_eq!(as_signed(0b100, 3), -4);
        assert_eq!(as_signed(5, 8), 5);
    }

    #[test]
    fn missing_input_defaults_zero() {
        let mut nl = Netlist::new("t");
        let a = nl.input_bus("a", 2);
        nl.output_bus("y", vec![a[0], a[1]]);
        let r = simulate(&nl, &HashMap::new(), 3, false);
        assert_eq!(r.outputs["y"], vec![0, 0, 0]);
    }
}

//! Levelized, word-parallel logic simulator (Questasim substitute).
//!
//! Because gates are stored in topological order, simulation is one
//! forward pass. Patterns are packed 64-per-u64 word, so a full test-set
//! stimulus of a few hundred vectors costs a handful of machine ops per
//! gate. The simulator doubles as:
//!
//!  * functional verifier — bit-exact against `axsum`'s integer model;
//!  * switching-activity source — per-gate toggle counts feed the dynamic
//!    power term in `estimate` (what PrimeTime does with Questasim VCDs).
//!
//! Hot-path architecture (see EXPERIMENTS.md §Perf): the DSE evaluates
//! thousands of netlists against ONE stimulus, so the stimulus is
//! bit-transposed once per sweep into a [`PackedStimulus`] and every
//! [`simulate_packed`] call borrows it, writing into a caller-owned
//! [`SimScratch`] so the per-design-point loop performs no heap
//! allocation. [`simulate`] is the compatibility wrapper that packs and
//! allocates per call.

use std::collections::HashMap;

use crate::netlist::Netlist;
use crate::pdk::CellKind;

pub mod plane;

pub use plane::{Lanes, Lanes4, PlaneWord};

/// Result of a simulation run.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Per output bus: one u64 value per pattern (LSB-first bus packing).
    pub outputs: HashMap<String, Vec<u64>>,
    /// Per-gate toggle counts across the pattern sequence (empty if
    /// toggle capture was off).
    pub toggles: Vec<u64>,
    pub patterns: usize,
}

// ---------------------------------------------------------------------------
// Packed stimulus: bit-transpose once, simulate many.
// ---------------------------------------------------------------------------

/// One input bus of a [`PackedStimulus`]: `words[bit * chunks + chunk]`
/// holds the 64-pattern word for bit lane `bit` of chunk `chunk`.
#[derive(Clone, Debug)]
struct PackedBus {
    name: String,
    width: usize,
    words: Vec<u64>,
}

/// A stimulus bit-transposed into per-net 64-pattern words.
///
/// Built once per sweep (or per `simulate` call on the legacy path) and
/// shared immutably by every simulation of netlists with the same input
/// interface (bus names; widths may differ — extra netlist bits read 0).
#[derive(Clone, Debug)]
pub struct PackedStimulus {
    patterns: usize,
    chunks: usize,
    buses: Vec<PackedBus>,
}

/// Bit-transpose one bus's value stream into `width` lane words of
/// `chunks` chunks each (`words[bit * chunks + chunk]`).
fn pack_bus(values: impl Iterator<Item = u64>, width: usize, chunks: usize) -> Vec<u64> {
    let mut words = vec![0u64; width * chunks];
    for (p, v) in values.enumerate() {
        let (chunk, pos) = (p / 64, p % 64);
        for (b, lane) in words.chunks_exact_mut(chunks).enumerate() {
            if (v >> b) & 1 == 1 {
                lane[chunk] |= 1u64 << pos;
            }
        }
    }
    words
}

impl PackedStimulus {
    /// Pack integer feature vectors into buses named `x0..x{din-1}`, each
    /// `width` bits wide — the input interface `synth::build_mlp`
    /// generates. An empty stimulus packs as a single all-zero pattern
    /// (matching the simulator's missing-input default).
    ///
    /// Every row is validated up front: a short (or long) feature vector,
    /// or a value outside `[0, 2^width)` (which the bit-transpose would
    /// silently mask to its low bits, diverging from the untransposed
    /// engines), returns a contextful error naming the offending row
    /// instead of panicking deep inside the packing loop.
    pub fn from_features(
        xs: &[Vec<i64>],
        din: usize,
        width: usize,
    ) -> Result<PackedStimulus, String> {
        // every non-negative i64 fits a width ≥ 63 bus, so only the
        // narrower (real) widths get an upper-bound check
        let out_of_range = |v: i64| v < 0 || (width < 63 && v >= 1i64 << width);
        for (p, x) in xs.iter().enumerate() {
            if x.len() != din {
                return Err(format!(
                    "stimulus row {p} has {} features, model expects din = {din}",
                    x.len()
                ));
            }
            if let Some((i, &v)) = x.iter().enumerate().find(|(_, &v)| out_of_range(v)) {
                return Err(format!(
                    "stimulus row {p} feature {i} = {v} outside [0, 2^{width})"
                ));
            }
        }
        let patterns = xs.len().max(1);
        let chunks = patterns.div_ceil(64);
        let buses = (0..din)
            .map(|i| PackedBus {
                name: format!("x{i}"),
                width,
                words: pack_bus(xs.iter().map(|x| x[i] as u64), width, chunks),
            })
            .collect();
        Ok(PackedStimulus {
            patterns,
            chunks,
            buses,
        })
    }

    /// Pack a name→values stimulus map against `nl`'s input interface.
    /// Missing buses pack as all-zero; missing patterns default to 0.
    pub fn for_netlist(
        nl: &Netlist,
        inputs: &HashMap<String, Vec<u64>>,
        patterns: usize,
    ) -> PackedStimulus {
        let chunks = patterns.div_ceil(64);
        let buses = nl
            .inputs
            .iter()
            .map(|bus| {
                let width = bus.nets.len();
                let vals = inputs
                    .get(&bus.name)
                    .map_or(&[][..], |v| v.as_slice());
                PackedBus {
                    name: bus.name.clone(),
                    width,
                    words: pack_bus(vals.iter().take(patterns).copied(), width, chunks),
                }
            })
            .collect();
        PackedStimulus {
            patterns,
            chunks,
            buses,
        }
    }

    pub fn patterns(&self) -> usize {
        self.patterns
    }

    /// Number of 64-pattern chunks.
    pub fn chunks(&self) -> usize {
        self.chunks
    }

    /// Bit-plane word of feature bus `i` (bus order as packed — for
    /// [`Self::from_features`] that is `x0..x{din-1}`), bit lane `bit`,
    /// chunk `chunk`. Out-of-range bus/lane/chunk reads 0, matching the
    /// simulator's missing-input default — this is the shared transpose
    /// the bit-sliced forward engine (`axsum::bitslice`) consumes.
    pub fn feature_lane(&self, i: usize, bit: usize, chunk: usize) -> u64 {
        match self.buses.get(i) {
            Some(b) if bit < b.width && chunk < self.chunks => b.words[bit * self.chunks + chunk],
            _ => 0,
        }
    }

    fn bus_index(&self, name: &str) -> Option<usize> {
        self.buses.iter().position(|b| b.name == name)
    }
}

/// Caller-owned simulation buffers: one per worker thread; reused across
/// design points so the sweep's inner loop does zero heap allocation
/// (buffers only grow, never shrink).
#[derive(Default)]
pub struct SimScratch {
    words: Vec<u64>,
    prev_last: Vec<u64>,
    /// Per-gate toggle counts of the last run (empty if capture was off).
    pub toggles: Vec<u64>,
    /// Per output bus of the last simulated netlist (same order as
    /// `nl.outputs`): one value per pattern.
    pub outputs: Vec<Vec<u64>>,
    /// Pattern count of the last run.
    pub patterns: usize,
    lane_map: Vec<usize>,
}

impl SimScratch {
    pub fn new() -> SimScratch {
        SimScratch::default()
    }

    /// Values of the named output bus from the last run.
    pub fn output<'a>(&'a self, nl: &Netlist, name: &str) -> Option<&'a [u64]> {
        nl.outputs
            .iter()
            .position(|b| b.name == name)
            .map(|i| self.outputs[i].as_slice())
    }

    /// Convert the last run into an owned [`SimResult`] (legacy shape).
    pub fn to_result(&self, nl: &Netlist) -> SimResult {
        SimResult {
            outputs: nl
                .outputs
                .iter()
                .zip(&self.outputs)
                .map(|(b, v)| (b.name.clone(), v.clone()))
                .collect(),
            toggles: self.toggles.clone(),
            patterns: self.patterns,
        }
    }
}

/// Simulate `nl` against a pre-packed stimulus, writing into `scratch`.
///
/// Bit-exact with [`simulate`]: same evaluation order, same fused toggle
/// counting, same output packing. The only differences are where the
/// input words come from (pre-transposed lanes instead of a per-bit
/// repacking loop) and where the buffers live.
pub fn simulate_packed(
    nl: &Netlist,
    stim: &PackedStimulus,
    capture_toggles: bool,
    scratch: &mut SimScratch,
) {
    let n = nl.gates.len();
    let patterns = stim.patterns;
    scratch.patterns = patterns;
    scratch.words.clear();
    scratch.words.resize(n, 0);
    scratch.prev_last.clear();
    scratch.prev_last.resize(n, 0);
    scratch.toggles.clear();
    if capture_toggles {
        scratch.toggles.resize(n, 0);
    }
    scratch.outputs.truncate(nl.outputs.len());
    while scratch.outputs.len() < nl.outputs.len() {
        scratch.outputs.push(Vec::new());
    }
    for out in scratch.outputs.iter_mut() {
        out.clear();
    }
    scratch.lane_map.clear();
    for bus in &nl.inputs {
        scratch
            .lane_map
            .push(stim.bus_index(&bus.name).unwrap_or(usize::MAX));
    }

    let words = &mut scratch.words;
    let prev_last = &mut scratch.prev_last;
    let toggles = &mut scratch.toggles;
    let chunks = patterns.div_ceil(64);

    for chunk in 0..chunks {
        let base = chunk * 64;
        let in_chunk = (patterns - base).min(64);

        // load inputs: one word copy per (net, chunk)
        for (bi, bus) in nl.inputs.iter().enumerate() {
            let lane = scratch.lane_map[bi];
            for (biti, &net) in bus.nets.iter().enumerate() {
                words[net as usize] = if lane != usize::MAX {
                    let pb = &stim.buses[lane];
                    if biti < pb.width && chunk < stim.chunks {
                        pb.words[biti * stim.chunks + chunk]
                    } else {
                        0
                    }
                } else {
                    0
                };
            }
        }

        // evaluate (+ fused toggle counting: one pass over the gate array
        // instead of two — see EXPERIMENTS.md §Perf)
        let mask = if in_chunk == 64 {
            u64::MAX
        } else {
            (1u64 << in_chunk) - 1
        };
        for (i, g) in nl.gates.iter().enumerate() {
            let w = match g.kind {
                CellKind::Input => words[i],
                CellKind::Const0 => 0,
                CellKind::Const1 => u64::MAX,
                CellKind::Buf => words[g.ins[0] as usize],
                CellKind::Inv => !words[g.ins[0] as usize],
                CellKind::And2 => words[g.ins[0] as usize] & words[g.ins[1] as usize],
                CellKind::Or2 => words[g.ins[0] as usize] | words[g.ins[1] as usize],
                CellKind::Nand2 => !(words[g.ins[0] as usize] & words[g.ins[1] as usize]),
                CellKind::Nor2 => !(words[g.ins[0] as usize] | words[g.ins[1] as usize]),
                CellKind::Xor2 => words[g.ins[0] as usize] ^ words[g.ins[1] as usize],
                CellKind::Xnor2 => !(words[g.ins[0] as usize] ^ words[g.ins[1] as usize]),
                CellKind::Mux2 => {
                    let s = words[g.ins[0] as usize];
                    (s & words[g.ins[1] as usize]) | (!s & words[g.ins[2] as usize])
                }
            };
            words[i] = w;
            if capture_toggles {
                let wm = w & mask;
                // transitions within the chunk: pattern p-1 -> p
                let within = (wm ^ (wm >> 1)) & (mask >> 1);
                let mut t = within.count_ones() as u64;
                // boundary transition from previous chunk's last pattern
                if chunk > 0 && (wm & 1) != prev_last[i] {
                    t += 1;
                }
                toggles[i] += t;
                prev_last[i] = (wm >> (in_chunk - 1)) & 1;
            }
        }

        // read outputs
        for (oi, bus) in nl.outputs.iter().enumerate() {
            let dst = &mut scratch.outputs[oi];
            for p in 0..in_chunk {
                let mut v = 0u64;
                for (biti, &net) in bus.nets.iter().enumerate() {
                    if (words[net as usize] >> p) & 1 == 1 {
                        v |= 1u64 << biti;
                    }
                }
                dst.push(v);
            }
        }
    }
}

/// Simulate `patterns` input vectors. `inputs` maps bus name -> per-pattern
/// unsigned values (LSB-first packing into the bus nets). Missing buses
/// default to all-zero. When `capture_toggles` is set, per-gate transition
/// counts over the pattern *sequence* are accumulated (stimulus order is
/// meaningful, as in a testbench).
///
/// Compatibility wrapper over [`simulate_packed`]: packs the stimulus and
/// allocates fresh buffers per call. Sweep-shaped callers should pack once
/// and reuse a [`SimScratch`] instead.
pub fn simulate(
    nl: &Netlist,
    inputs: &HashMap<String, Vec<u64>>,
    patterns: usize,
    capture_toggles: bool,
) -> SimResult {
    let stim = PackedStimulus::for_netlist(nl, inputs, patterns);
    let mut scratch = SimScratch::new();
    simulate_packed(nl, &stim, capture_toggles, &mut scratch);
    SimResult {
        outputs: nl
            .outputs
            .iter()
            .zip(scratch.outputs.iter_mut())
            .map(|(b, v)| (b.name.clone(), std::mem::take(v)))
            .collect(),
        toggles: scratch.toggles,
        patterns: scratch.patterns,
    }
}

/// One-pattern convenience evaluator for tests: returns bus name -> value.
pub fn eval_once(nl: &Netlist, assignments: &[(&str, u64)]) -> HashMap<String, u64> {
    let inputs: HashMap<String, Vec<u64>> = assignments
        .iter()
        .map(|(n, v)| (n.to_string(), vec![*v]))
        .collect();
    let r = simulate(nl, &inputs, 1, false);
    r.outputs
        .into_iter()
        .map(|(k, mut v)| (k, v.pop().unwrap()))
        .collect()
}

/// Signed read helper: interpret a bus value of width `w` as two's
/// complement.
pub fn as_signed(v: u64, w: usize) -> i64 {
    if w == 0 || w >= 64 {
        return v as i64;
    }
    let m = 1u64 << (w - 1);
    (((v & ((1u64 << w) - 1)) ^ m) as i64) - m as i64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gates_truth_tables() {
        let mut nl = Netlist::new("t");
        let v = nl.input_bus("v", 2);
        let (a, b) = (v[0], v[1]);
        let and = nl.and(a, b);
        let or = nl.or(a, b);
        let xor = nl.xor(a, b);
        let na = nl.not(a);
        nl.output_bus("and", vec![and]);
        nl.output_bus("or", vec![or]);
        nl.output_bus("xor", vec![xor]);
        nl.output_bus("na", vec![na]);
        for v_in in 0..4u64 {
            let out = eval_once(&nl, &[("v", v_in)]);
            let (a, b) = (v_in & 1, (v_in >> 1) & 1);
            assert_eq!(out["and"], a & b);
            assert_eq!(out["or"], a | b);
            assert_eq!(out["xor"], a ^ b);
            assert_eq!(out["na"], 1 - a);
        }
    }

    #[test]
    fn mux_truth_table() {
        let mut nl = Netlist::new("t");
        let v = nl.input_bus("v", 3);
        let m = nl.mux(v[0], v[1], v[2]);
        nl.output_bus("m", vec![m]);
        for v_in in 0..8u64 {
            let out = eval_once(&nl, &[("v", v_in)]);
            let (s, a, b) = (v_in & 1, (v_in >> 1) & 1, (v_in >> 2) & 1);
            assert_eq!(out["m"], if s == 1 { a } else { b });
        }
    }

    #[test]
    fn multi_pattern_matches_single() {
        let mut nl = Netlist::new("t");
        let a = nl.input_bus("a", 4);
        let b = nl.input_bus("b", 4);
        let mut acc = Vec::new();
        for i in 0..4 {
            acc.push(nl.xor(a[i], b[i]));
        }
        nl.output_bus("y", acc);
        let mut rng = Rng::new(5);
        let pats = 200;
        let av: Vec<u64> = (0..pats).map(|_| rng.below(16) as u64).collect();
        let bv: Vec<u64> = (0..pats).map(|_| rng.below(16) as u64).collect();
        let mut inputs = HashMap::new();
        inputs.insert("a".to_string(), av.clone());
        inputs.insert("b".to_string(), bv.clone());
        let r = simulate(&nl, &inputs, pats, true);
        for p in 0..pats {
            let one = eval_once(&nl, &[("a", av[p]), ("b", bv[p])]);
            assert_eq!(r.outputs["y"][p], one["y"], "pattern {p}");
        }
    }

    #[test]
    fn toggle_counting_alternating() {
        // single inverter driven by alternating input: every pattern
        // transition toggles both nets.
        let mut nl = Netlist::new("t");
        let a = nl.input_bus("a", 1);
        let ia = nl.not(a[0]);
        nl.output_bus("y", vec![ia]);
        let pats = 130; // crosses two word boundaries
        let vals: Vec<u64> = (0..pats).map(|p| (p % 2) as u64).collect();
        let mut inputs = HashMap::new();
        inputs.insert("a".to_string(), vals);
        let r = simulate(&nl, &inputs, pats, true);
        // input net toggles pats-1 times; inverter follows
        let inv_idx = ia as usize;
        assert_eq!(r.toggles[inv_idx], (pats - 1) as u64);
    }

    #[test]
    fn toggle_counting_constant_input() {
        let mut nl = Netlist::new("t");
        let a = nl.input_bus("a", 1);
        let ia = nl.not(a[0]);
        nl.output_bus("y", vec![ia]);
        let vals: Vec<u64> = vec![1; 100];
        let mut inputs = HashMap::new();
        inputs.insert("a".to_string(), vals);
        let r = simulate(&nl, &inputs, 100, true);
        assert_eq!(r.toggles[ia as usize], 0);
    }

    #[test]
    fn as_signed_roundtrip() {
        assert_eq!(as_signed(0b111, 3), -1);
        assert_eq!(as_signed(0b011, 3), 3);
        assert_eq!(as_signed(0b100, 3), -4);
        assert_eq!(as_signed(5, 8), 5);
    }

    #[test]
    fn missing_input_defaults_zero() {
        let mut nl = Netlist::new("t");
        let a = nl.input_bus("a", 2);
        nl.output_bus("y", vec![a[0], a[1]]);
        let r = simulate(&nl, &HashMap::new(), 3, false);
        assert_eq!(r.outputs["y"], vec![0, 0, 0]);
    }

    #[test]
    fn packed_scratch_reuse_across_netlists() {
        // one scratch driven across two different-size netlists must
        // produce the same results as fresh simulate() calls.
        let mut rng = Rng::new(9);
        let mut scratch = SimScratch::new();
        for width in [3usize, 7] {
            let mut nl = Netlist::new("t");
            let a = nl.input_bus("a", width);
            let b = nl.input_bus("b", width);
            let bits: Vec<_> = (0..width).map(|i| nl.xor(a[i], b[i])).collect();
            let y0 = bits[0];
            nl.output_bus("y", bits);
            nl.output_bus("lsb", vec![y0]);
            let pats = 100;
            let hi = 1usize << width;
            let av: Vec<u64> = (0..pats).map(|_| rng.below(hi) as u64).collect();
            let bv: Vec<u64> = (0..pats).map(|_| rng.below(hi) as u64).collect();
            let mut inputs = HashMap::new();
            inputs.insert("a".to_string(), av);
            inputs.insert("b".to_string(), bv);
            let stim = PackedStimulus::for_netlist(&nl, &inputs, pats);
            simulate_packed(&nl, &stim, true, &mut scratch);
            let want = simulate(&nl, &inputs, pats, true);
            assert_eq!(scratch.output(&nl, "y").unwrap(), &want.outputs["y"][..]);
            assert_eq!(
                scratch.output(&nl, "lsb").unwrap(),
                &want.outputs["lsb"][..]
            );
            assert_eq!(scratch.toggles, want.toggles);
            assert_eq!(scratch.to_result(&nl).patterns, pats);
        }
    }

    #[test]
    fn from_features_matches_bus_map_packing() {
        let mut rng = Rng::new(21);
        let din = 5;
        let xs: Vec<Vec<i64>> = (0..130)
            .map(|_| (0..din).map(|_| rng.range_i64(0, 15)).collect())
            .collect();
        // netlist echoing every input bit
        let mut nl = Netlist::new("echo");
        let mut all = Vec::new();
        for i in 0..din {
            let b = nl.input_bus(format!("x{i}"), 4);
            all.extend(b);
        }
        nl.output_bus("all", all);
        let mut inputs: HashMap<String, Vec<u64>> = HashMap::new();
        for i in 0..din {
            inputs.insert(format!("x{i}"), xs.iter().map(|x| x[i] as u64).collect());
        }
        let via_map = PackedStimulus::for_netlist(&nl, &inputs, xs.len());
        let via_features = PackedStimulus::from_features(&xs, din, 4).unwrap();
        let mut s1 = SimScratch::new();
        let mut s2 = SimScratch::new();
        simulate_packed(&nl, &via_map, true, &mut s1);
        simulate_packed(&nl, &via_features, true, &mut s2);
        assert_eq!(s1.outputs, s2.outputs);
        assert_eq!(s1.toggles, s2.toggles);
    }

    #[test]
    fn short_feature_row_is_a_contextful_error_not_a_panic() {
        // regression: a 2-feature row against din = 3 used to index out
        // of bounds deep inside the bit-transpose loop
        let xs = vec![vec![1i64, 2, 3], vec![1i64, 2]];
        let err = PackedStimulus::from_features(&xs, 3, 4).unwrap_err();
        assert!(err.contains("row 1"), "{err}");
        assert!(err.contains("din = 3"), "{err}");
        // long rows are rejected too (silently dropping features would
        // hide a caller bug)
        let err = PackedStimulus::from_features(&[vec![0i64; 5]], 3, 4).unwrap_err();
        assert!(err.contains("5 features"), "{err}");
        // out-of-range values are rejected too — the transpose would
        // silently mask them to the low `width` bits, diverging from the
        // untransposed engines
        let err = PackedStimulus::from_features(&[vec![0, 16, 0]], 3, 4).unwrap_err();
        assert!(err.contains("feature 1 = 16"), "{err}");
        let err = PackedStimulus::from_features(&[vec![0, 0, -1]], 3, 4).unwrap_err();
        assert!(err.contains("feature 2 = -1"), "{err}");
    }

    #[test]
    fn feature_lane_out_of_range_reads_zero() {
        let xs = vec![vec![15i64, 1]];
        let stim = PackedStimulus::from_features(&xs, 2, 4).unwrap();
        assert_eq!(stim.chunks(), 1);
        assert_eq!(stim.feature_lane(0, 0, 0), 1); // bit 0 of 15, pattern 0
        assert_eq!(stim.feature_lane(0, 3, 0), 1);
        assert_eq!(stim.feature_lane(1, 1, 0), 0); // bit 1 of 1
        assert_eq!(stim.feature_lane(0, 4, 0), 0); // lane past width
        assert_eq!(stim.feature_lane(2, 0, 0), 0); // bus past din
        assert_eq!(stim.feature_lane(0, 0, 1), 0); // chunk past end
    }

    #[test]
    fn empty_feature_stimulus_is_one_zero_pattern() {
        let stim = PackedStimulus::from_features(&[], 3, 4).unwrap();
        assert_eq!(stim.patterns(), 1);
        let mut nl = Netlist::new("t");
        let x0 = nl.input_bus("x0", 4);
        nl.output_bus("y", x0);
        let mut scratch = SimScratch::new();
        simulate_packed(&nl, &stim, true, &mut scratch);
        assert_eq!(scratch.output(&nl, "y").unwrap(), &[0u64][..]);
    }
}

//! `repro` — leader entrypoint of the co-design framework.
//!
//! Every subcommand regenerates one table/figure of the paper (DESIGN.md
//! §6 maps them); `repro all` runs the whole evaluation. The binary is
//! self-contained after `make artifacts`: Python never runs here.

use axmlp::cli::{Args, USAGE};
use axmlp::experiments::{self, BackendKind, ExpConfig};
use axmlp::runtime::Runtime;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let Some(cmd) = args.command.clone() else {
        println!("{USAGE}");
        return;
    };
    if args.flag_bool("quiet") {
        axmlp::obs::set_level(axmlp::obs::Level::Warn);
    } else if args.flag_bool("verbose") {
        axmlp::obs::set_level(axmlp::obs::Level::Debug);
    }
    let metrics_out = args.flag("metrics-out").map(std::path::PathBuf::from);
    if metrics_out.is_some() {
        axmlp::obs::set_enabled(true);
    }
    let result = run(&cmd, &args);
    // the snapshot is written even when the run failed: a partial span
    // tree is exactly what a failed run needs for a post-mortem
    if let Some(path) = &metrics_out {
        match axmlp::obs::write_metrics(path) {
            Ok(()) => axmlp::log!(Info, "wrote {}", path.display()),
            Err(e) => axmlp::log!(Warn, "could not write {}: {e}", path.display()),
        }
        axmlp::log!(Info, "{}", axmlp::obs::render());
    }
    if let Err(e) = result {
        axmlp::log!(Error, "{e}");
        std::process::exit(1);
    }
}

fn exp_config(args: &Args) -> Result<ExpConfig, String> {
    let mut cfg = ExpConfig {
        seed: args.flag_u64("seed", 2023)?,
        quick: args.flag_bool("quick"),
        threads: args.flag_usize("threads", axmlp::util::pool::default_threads())?,
        ..Default::default()
    };
    if let Some(ds) = args.flag_list("datasets") {
        for k in &ds {
            if axmlp::datasets::registry::by_key(k).is_none() {
                return Err(format!(
                    "unknown dataset key `{k}` (valid keys: {})",
                    axmlp::datasets::registry::valid_keys().join(", ")
                ));
            }
        }
        cfg.datasets = ds;
    }
    cfg.backend = match args.flag("backend") {
        None | Some("pjrt") => BackendKind::Pjrt,
        Some("rust") => BackendKind::Rust,
        Some(b) => return Err(format!("unknown backend `{b}` (pjrt|rust)")),
    };
    cfg.engine = match args.flag("engine") {
        None | Some("flat") => axmlp::dse::EvalBackend::Flat,
        Some("bitslice") => axmlp::dse::EvalBackend::BitSlice,
        Some("bitslice128") => axmlp::dse::EvalBackend::BitSlice128,
        Some("bitslice256") => axmlp::dse::EvalBackend::BitSlice256,
        Some(e) => {
            return Err(format!(
                "unknown engine `{e}` (flat|bitslice|bitslice128|bitslice256)"
            ))
        }
    };
    Ok(cfg)
}

/// NSGA-II hyperparameters for the `search` subcommand: sized down under
/// `--quick`, overridable with `--pop` / `--gens`.
fn search_config(args: &Args, cfg: &ExpConfig) -> Result<axmlp::search::SearchConfig, String> {
    let (def_pop, def_gens) = if cfg.quick { (24, 12) } else { (48, 32) };
    let scfg = axmlp::search::SearchConfig {
        seed: cfg.seed,
        pop_size: args.flag_usize("pop", def_pop)?,
        generations: args.flag_usize("gens", def_gens)?,
        log: args.flag_bool("search-log"),
        ..Default::default()
    };
    if scfg.pop_size < 4 {
        return Err("--pop must be at least 4".to_string());
    }
    if scfg.generations == 0 {
        return Err("--gens must be at least 1".to_string());
    }
    Ok(scfg)
}

fn run(cmd: &str, args: &Args) -> anyhow::Result<()> {
    match cmd {
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        "smoke" => {
            let rt = Runtime::new(Runtime::default_dir())?;
            rt.smoke()?;
            axmlp::log!(
                Info,
                "runtime OK: platform={}, {} topologies indexed",
                rt.platform(),
                rt.index.topologies.len()
            );
            Ok(())
        }
        "table2" => experiments::exp_table2(&exp_config(args).map_err(anyhow::Error::msg)?),
        "fig2a" => experiments::exp_fig2a(&exp_config(args).map_err(anyhow::Error::msg)?),
        "fig2b" => experiments::exp_fig2b(&exp_config(args).map_err(anyhow::Error::msg)?),
        "fig3" => experiments::exp_fig3(&exp_config(args).map_err(anyhow::Error::msg)?),
        "fig5" => experiments::exp_fig5(&exp_config(args).map_err(anyhow::Error::msg)?),
        "fig6" | "fig7" | "fig8" => {
            experiments::exp_fig6(&exp_config(args).map_err(anyhow::Error::msg)?).map(|_| ())
        }
        "fig9" => experiments::exp_fig9(&exp_config(args).map_err(anyhow::Error::msg)?),
        "alpha" => experiments::exp_alpha(&exp_config(args).map_err(anyhow::Error::msg)?),
        "refine" => experiments::exp_refine(&exp_config(args).map_err(anyhow::Error::msg)?),
        "search" => {
            let cfg = exp_config(args).map_err(anyhow::Error::msg)?;
            let scfg = search_config(args, &cfg).map_err(anyhow::Error::msg)?;
            experiments::exp_search(&cfg, &scfg, args.flag_bool("families"))
        }
        "sweep" => {
            let cfg = exp_config(args).map_err(anyhow::Error::msg)?;
            let shards = args.flag_usize("shards", 4).map_err(anyhow::Error::msg)?;
            if shards == 0 {
                return Err(anyhow::Error::msg("--shards must be at least 1"));
            }
            let dir = args.flag("checkpoint-dir").unwrap_or("results/shard_ckpt");
            let claim = if args.flag_bool("claim") {
                let lease_ms = args.flag_u64("lease-ms", 5000).map_err(anyhow::Error::msg)?;
                if lease_ms == 0 {
                    return Err(anyhow::Error::msg("--lease-ms must be at least 1"));
                }
                Some(axmlp::dse::shard::ClaimConfig {
                    owner_id: args
                        .flag("owner-id")
                        .map_or_else(|| format!("pid{}", std::process::id()), str::to_string),
                    lease_ms,
                    kill_at: None,
                })
            } else {
                None
            };
            experiments::exp_shard(&cfg, shards, dir, args.flag_bool("resume"), claim)
        }
        "conform" => {
            let cfg = exp_config(args).map_err(anyhow::Error::msg)?;
            let cases = args.flag_u64("cases", 256).map_err(anyhow::Error::msg)?;
            experiments::exp_conform(&cfg, cases, args.flag_bool("bless"))
        }
        "lint" => experiments::exp_lint(&exp_config(args).map_err(anyhow::Error::msg)?),
        "all" => {
            let cfg = exp_config(args).map_err(anyhow::Error::msg)?;
            experiments::exp_table2(&cfg)?;
            experiments::exp_fig2a(&cfg)?;
            experiments::exp_fig2b(&cfg)?;
            experiments::exp_fig3(&cfg)?;
            experiments::exp_fig5(&cfg)?;
            experiments::exp_fig6(&cfg)?;
            experiments::exp_fig9(&cfg)
        }
        "verilog" => cmd_verilog(args),
        other => {
            eprintln!("unknown command `{other}`\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

/// Emit the bespoke Verilog RTL for one dataset's co-designed MLP.
fn cmd_verilog(args: &Args) -> anyhow::Result<()> {
    use axmlp::coordinator::{run_dataset, PipelineConfig, SharedContext};
    use axmlp::retrain::backend_rust::RustBackend;
    use axmlp::synth::{build_mlp, MlpCircuitSpec, NeuronStyle};

    let key = args.flag("dataset").unwrap_or("ma").to_string();
    let threshold: f64 = args
        .flag("threshold")
        .unwrap_or("0.01")
        .parse()
        .map_err(|_| anyhow::anyhow!("--threshold expects a float"))?;
    let out_path = args
        .flag("out")
        .map_or_else(|| format!("results/{key}_axmlp.v"), |s| s.to_string());

    let seed = args.flag_u64("seed", 2023).map_err(anyhow::Error::msg)?;
    let ds = axmlp::datasets::load(&key, seed)?;
    let mut cfg = PipelineConfig {
        thresholds: vec![threshold],
        ..Default::default()
    };
    cfg.dse.max_g_levels = 4;
    cfg.dse.max_eval = 800;
    let ctx = SharedContext::new();
    let mut be = RustBackend;
    let outcome = run_dataset(&ds, &cfg, &ctx, &mut be)?;
    let tr = &outcome.thresholds[0];
    let spec = MlpCircuitSpec {
        name: format!("axmlp_{key}"),
        weights: tr.model.w.clone(),
        biases: tr.model.b.clone(),
        shifts: tr.design.plan.shifts.clone(),
        in_bits: tr.model.in_bits,
        style: NeuronStyle::AxSum,
    };
    let nl = build_mlp(&spec);
    let v = axmlp::verilog::to_verilog(&nl);
    let _ = std::fs::create_dir_all("results");
    std::fs::write(&out_path, &v)?;
    axmlp::log!(
        Info,
        "wrote {out_path}: module axmlp_{key}, {} cells, {:.2} cm², {:.1} mW, acc(test) {:.3}",
        nl.n_cells(),
        tr.design.costs.area_cm2(),
        tr.design.costs.power_mw,
        tr.design.acc_test,
    );
    Ok(())
}

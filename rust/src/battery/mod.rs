//! Printed-battery feasibility classification (paper Fig. 8).
//!
//! The paper classifies each MLP's power draw against the three printed
//! batteries available at the time: Blue Spark (3 mW), Zinergy (15 mW) and
//! Molex (30 mW); anything above 30 mW has "no adequate power supply".

/// Battery tiers, ordered by capacity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Battery {
    /// Blue Spark, 3 mW.
    BlueSpark,
    /// Zinergy, 15 mW.
    Zinergy,
    /// Molex, 30 mW.
    Molex,
    /// > 30 mW: not battery-powerable with printed batteries.
    None,
}

impl Battery {
    pub fn limit_mw(self) -> f64 {
        match self {
            Battery::BlueSpark => 3.0,
            Battery::Zinergy => 15.0,
            Battery::Molex => 30.0,
            Battery::None => f64::INFINITY,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Battery::BlueSpark => "BlueSpark(3mW)",
            Battery::Zinergy => "Zinergy(15mW)",
            Battery::Molex => "Molex(30mW)",
            Battery::None => "none(>30mW)",
        }
    }
}

/// Smallest battery that can power the circuit.
pub fn classify(power_mw: f64) -> Battery {
    if power_mw <= 3.0 {
        Battery::BlueSpark
    } else if power_mw <= 15.0 {
        Battery::Zinergy
    } else if power_mw <= 30.0 {
        Battery::Molex
    } else {
        Battery::None
    }
}

/// Count how many of the given power figures are battery-powerable at all.
pub fn n_powerable(powers_mw: &[f64]) -> usize {
    powers_mw
        .iter()
        .filter(|&&p| classify(p) != Battery::None)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_boundaries() {
        assert_eq!(classify(0.5), Battery::BlueSpark);
        assert_eq!(classify(3.0), Battery::BlueSpark);
        assert_eq!(classify(3.01), Battery::Zinergy);
        assert_eq!(classify(15.0), Battery::Zinergy);
        assert_eq!(classify(29.9), Battery::Molex);
        assert_eq!(classify(30.0), Battery::Molex);
        assert_eq!(classify(30.1), Battery::None);
    }

    #[test]
    fn powerable_count() {
        // paper Table 2 baseline: only V2 (13 mW) and MA (27 mW) fit
        let table2 = [98.0, 97.0, 53.0, 213.0, 36.0, 36.0, 41.0, 40.0, 13.0, 27.0];
        assert_eq!(n_powerable(&table2), 2);
    }

    #[test]
    fn ordering() {
        assert!(Battery::BlueSpark < Battery::Zinergy);
        assert!(Battery::Molex < Battery::None);
    }
}

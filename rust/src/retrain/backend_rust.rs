//! Native retraining backend — a bit-faithful mirror of the JAX
//! `train_step` in `python/compile/model.py` (STE projection, softmax
//! cross-entropy with temperature, SGD, ±W_MAX shadow clamp).
//!
//! Used for tests and artifact-less runs; the production path is
//! `runtime::PjrtBackend`, which executes the AOT-lowered step. Both
//! backends implement the same [`TrainBackend`] epoch contract, and the
//! integration tests assert they reach equivalent retraining outcomes.

use super::{EpochStats, RetrainState, TrainBackend};
use crate::fixed::W_MAX;
use crate::mlp::train::softmax;

pub struct RustBackend;

impl TrainBackend for RustBackend {
    fn train_epoch(
        &mut self,
        st: &mut RetrainState,
        vc: &[f32],
        lr: f32,
    ) -> anyhow::Result<EpochStats> {
        let (din, hid, dout) = (st.din, st.hidden, st.dout);
        let n = st.n;
        let perm = st.rng.permutation(n);
        let mut changed_total = 0usize;
        let mut loss_sum = 0.0f64;
        let mut batches = 0usize;

        for chunk in perm.chunks(st.batch) {
            // projection before the step (for the changed counter)
            let p1_old = RetrainState::project_slice(&st.w1, vc);
            let p2_old = RetrainState::project_slice(&st.w2, vc);

            // forward/backward with projected weights (STE)
            let bsz = chunk.len();
            let mut gw1 = vec![0.0f32; din * hid];
            let mut gb1 = vec![0.0f32; hid];
            let mut gw2 = vec![0.0f32; hid * dout];
            let mut gb2 = vec![0.0f32; dout];
            let mut loss = 0.0f32;
            for &idx in chunk {
                let x = &st.x[idx * din..(idx + 1) * din];
                let y = st.y[idx];
                // z1 = x @ w1q + b1 ; h = relu(z1)
                let mut z1 = vec![0.0f32; hid];
                for j in 0..hid {
                    let mut acc = st.b1[j];
                    for i in 0..din {
                        acc += x[i] * p1_old[i * hid + j];
                    }
                    z1[j] = acc;
                }
                let h: Vec<f32> = z1.iter().map(|&z| z.max(0.0)).collect();
                // logits = (h @ w2q + b2) / temp
                let mut logits = vec![0.0f32; dout];
                for o in 0..dout {
                    let mut acc = st.b2[o];
                    for j in 0..hid {
                        acc += h[j] * p2_old[j * dout + o];
                    }
                    logits[o] = acc / st.temp;
                }
                let mut p = logits.clone();
                softmax(&mut p);
                // loss via log-sum-exp (matches jax log_softmax exactly,
                // including deep-saturation values the clamped ln(p) form
                // would truncate)
                let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let lse = m + logits.iter().map(|&v| (v - m).exp()).sum::<f32>().ln();
                loss += lse - logits[y];
                // backward
                let mut dl = p;
                dl[y] -= 1.0;
                for v in dl.iter_mut() {
                    *v /= st.temp * bsz as f32;
                }
                for o in 0..dout {
                    gb2[o] += dl[o] * st.temp; // b2 is pre-division... see note
                    for j in 0..hid {
                        gw2[j * dout + o] += h[j] * dl[o];
                    }
                }
                for j in 0..hid {
                    if z1[j] <= 0.0 {
                        continue;
                    }
                    let mut dh = 0.0f32;
                    for o in 0..dout {
                        dh += dl[o] * p2_old[j * dout + o];
                    }
                    gb1[j] += dh;
                    for i in 0..din {
                        gw1[i * hid + j] += x[i] * dh;
                    }
                }
            }
            // NOTE on gb2: logits = (h@w2 + b2)/temp, so dL/db2 = dl_pre/temp
            // where dl_pre = softmax-onehot. Our `dl` is already divided by
            // temp, hence gb2 += dl*temp reconstructs dl_pre... but the jax
            // model differentiates through the same expression, giving
            // dL/db2 = (softmax-onehot)/temp. Keep the jax semantics:
            for v in gb2.iter_mut() {
                *v /= st.temp;
            }

            // SGD update + clamp (matches jnp.clip(-W_MAX, W_MAX))
            let wm = W_MAX as f32;
            for (w, g) in st.w1.iter_mut().zip(&gw1) {
                *w = (*w - lr * g).clamp(-wm, wm);
            }
            for (w, g) in st.w2.iter_mut().zip(&gw2) {
                *w = (*w - lr * g).clamp(-wm, wm);
            }
            for (b, g) in st.b1.iter_mut().zip(&gb1) {
                *b -= lr * g;
            }
            for (b, g) in st.b2.iter_mut().zip(&gb2) {
                *b -= lr * g;
            }

            let p1_new = RetrainState::project_slice(&st.w1, vc);
            let p2_new = RetrainState::project_slice(&st.w2, vc);
            changed_total += p1_old
                .iter()
                .zip(&p1_new)
                .filter(|(a, b)| a != b)
                .count()
                + p2_old.iter().zip(&p2_new).filter(|(a, b)| a != b).count();
            loss_sum += (loss / bsz as f32) as f64;
            batches += 1;
        }

        Ok(EpochStats {
            changed: changed_total,
            loss: loss_sum / batches.max(1) as f64,
        })
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::{cluster_coefficients, multiplier_area_lut};
    use crate::fixed::{quantize, quantize_inputs};
    use crate::mlp::{train::TrainConfig, Mlp};
    use crate::pdk::EgtLibrary;
    use crate::retrain::{printing_friendly_retrain, AreaModel, RetrainConfig};
    use crate::util::rng::Rng;

    fn trained_toy() -> (crate::fixed::QuantMlp, Vec<Vec<i64>>, Vec<usize>) {
        let mut rng = Rng::new(21);
        // separable 3-class blobs in 4D
        let mut xs: Vec<Vec<f32>> = Vec::new();
        let mut ys = Vec::new();
        let centers = [
            [0.2f64, 0.2, 0.8, 0.5],
            [0.8, 0.3, 0.2, 0.5],
            [0.5, 0.8, 0.5, 0.1],
        ];
        for i in 0..360 {
            let c = i % 3;
            xs.push(
                centers[c]
                    .iter()
                    .map(|&m| rng.gauss(m, 0.08).clamp(0.0, 1.0) as f32)
                    .collect(),
            );
            ys.push(c);
        }
        let mut m = Mlp::new_random(4, 3, 3, &mut rng);
        crate::mlp::train::train(
            &mut m,
            &xs,
            &ys,
            &TrainConfig {
                epochs: 150,
                target_train_acc: 0.97,
                ..Default::default()
            },
        );
        let q = quantize(&m);
        (q, quantize_inputs(&xs), ys)
    }

    #[test]
    fn epoch_reduces_loss_with_dense_vc() {
        let (q, xs, ys) = trained_toy();
        let mut st = crate::retrain::RetrainState::from_quant(&q, &xs, &ys, 64, 3);
        let vc: Vec<f32> = (-127..=127).map(|v| v as f32).collect();
        let mut be = RustBackend;
        let s1 = be.train_epoch(&mut st, &vc, 1.0).unwrap();
        let mut last = s1.loss;
        for _ in 0..5 {
            let s = be.train_epoch(&mut st, &vc, 1.0).unwrap();
            last = s.loss;
        }
        assert!(last <= s1.loss + 0.05, "loss {last} vs {}", s1.loss);
    }

    #[test]
    fn zero_lr_changes_nothing() {
        let (q, xs, ys) = trained_toy();
        let mut st = crate::retrain::RetrainState::from_quant(&q, &xs, &ys, 64, 3);
        let vc: Vec<f32> = vec![0.0, 64.0, -64.0];
        let before = st.w1.clone();
        let mut be = RustBackend;
        let s = be.train_epoch(&mut st, &vc, 0.0).unwrap();
        assert_eq!(s.changed, 0);
        assert_eq!(st.w1, before);
    }

    #[test]
    fn full_algorithm_meets_threshold_and_saves_area() {
        let (q, xs, ys) = trained_toy();
        let lib = EgtLibrary::egt_v1();
        let lut = multiplier_area_lut(4, 127, &lib, 8);
        let clusters = cluster_coefficients(&lut, 4, 42);
        let area = AreaModel::for_model(&q, &lib, 8);
        let cfg = RetrainConfig {
            threshold: 0.02,
            epochs_per_level: 8,
            ..Default::default()
        };
        let mut be = RustBackend;
        let out =
            printing_friendly_retrain(&q, &xs, &ys, &clusters, &area, &cfg, &mut be).unwrap();
        assert!(out.met_threshold, "retraining should reach T=2% on blobs");
        assert!(
            out.acc_train >= out.acc0_train - cfg.threshold - 1e-9,
            "acc {} vs acc0 {}",
            out.acc_train,
            out.acc0_train
        );
        assert!(out.ar < out.ar0, "area must shrink: {} vs {}", out.ar, out.ar0);
        // all coefficients drawn from the consumed clusters
        let vc: Vec<i64> = clusters.vc_for_level(out.clusters_used - 1);
        for layer in &out.q.w {
            for row in layer {
                for &w in row {
                    assert!(vc.contains(&w), "w={w} outside VC");
                }
            }
        }
    }
}

//! Printing-friendly MLP retraining — paper Algorithm 1 (§3.2).
//!
//! The driver owns the paper's control flow: progressively enlarge the
//! allowed coefficient set VC cluster by cluster, retrain `m` epochs per
//! level with projection onto VC, boost the learning rate when projection
//! stalls, score candidates with Eq. (1), and stop at the first level
//! whose best model is within the accuracy-loss threshold.
//!
//! The *gradient work* is behind [`TrainBackend`]: the production path
//! executes the AOT-compiled JAX train-step artifact via PJRT
//! (`runtime::PjrtBackend`), and [`backend_rust::RustBackend`] is a
//! bit-faithful native mirror used for tests and artifact-less runs.

pub mod backend_rust;

use crate::clustering::Clusters;
use crate::fixed::{QuantMlp, W_MAX};
use crate::util::rng::Rng;

/// Epoch-level statistics a backend reports to the driver.
#[derive(Clone, Copy, Debug, Default)]
pub struct EpochStats {
    /// Count of coefficients whose projection changed during the epoch.
    pub changed: usize,
    /// Mean minibatch loss over the epoch.
    pub loss: f64,
}

/// One epoch of projected (STE) SGD over the training set.
pub trait TrainBackend {
    fn train_epoch(
        &mut self,
        state: &mut RetrainState,
        vc: &[f32],
        lr: f32,
    ) -> anyhow::Result<EpochStats>;

    fn name(&self) -> &'static str;
}

/// Mutable retraining state in the *JAX layout* (`w1[i·hidden + j]`,
/// input-major) so the PJRT backend can feed literals without reshaping.
#[derive(Clone, Debug)]
pub struct RetrainState {
    pub din: usize,
    pub hidden: usize,
    pub dout: usize,
    /// Shadow (full-precision) coefficients, integer domain.
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    /// Training inputs, integer-valued f32, flattened [n × din].
    pub x: Vec<f32>,
    pub y: Vec<usize>,
    pub n: usize,
    /// Softmax temperature mapping integer logits to float magnitudes.
    pub temp: f32,
    pub batch: usize,
    pub rng: Rng,
}

impl RetrainState {
    /// Initialize from the quantized MLP0 and integer training data.
    pub fn from_quant(q0: &QuantMlp, x_int: &[Vec<i64>], y: &[usize], batch: usize, seed: u64) -> Self {
        let (din, hidden, dout) = (q0.din(), q0.hidden(), q0.dout());
        let mut w1 = vec![0.0f32; din * hidden];
        for (j, row) in q0.w[0].iter().enumerate() {
            for (i, &w) in row.iter().enumerate() {
                w1[i * hidden + j] = w as f32;
            }
        }
        let mut w2 = vec![0.0f32; hidden * dout];
        for (o, row) in q0.w[1].iter().enumerate() {
            for (j, &w) in row.iter().enumerate() {
                w2[j * dout + o] = w as f32;
            }
        }
        let mut x = Vec::with_capacity(x_int.len() * din);
        for row in x_int {
            x.extend(row.iter().map(|&v| v as f32));
        }
        RetrainState {
            din,
            hidden,
            dout,
            w1,
            b1: q0.b[0].iter().map(|&b| b as f32).collect(),
            w2,
            b2: q0.b[1].iter().map(|&b| b as f32).collect(),
            x,
            y: y.to_vec(),
            n: x_int.len(),
            temp: q0.logit_temperature().max(1.0) as f32,
            batch,
            rng: Rng::new(seed),
        }
    }

    /// Nearest-VC projection (first-index tie-break, mirroring jax argmin).
    pub fn project_val(w: f32, vc: &[f32]) -> f32 {
        let mut best = vc[0];
        let mut bd = f32::INFINITY;
        for &v in vc {
            let d = (w - v).abs();
            if d < bd {
                bd = d;
                best = v;
            }
        }
        best
    }

    pub fn project_slice(ws: &[f32], vc: &[f32]) -> Vec<f32> {
        ws.iter().map(|&w| Self::project_val(w, vc)).collect()
    }

    /// Projected hardware model (coefficients snapped to VC, biases
    /// rounded to integers).
    pub fn to_quant(&self, vc: &[f32], reference: &QuantMlp) -> QuantMlp {
        let p1 = Self::project_slice(&self.w1, vc);
        let p2 = Self::project_slice(&self.w2, vc);
        let mut w = vec![
            vec![vec![0i64; self.din]; self.hidden],
            vec![vec![0i64; self.hidden]; self.dout],
        ];
        for i in 0..self.din {
            for j in 0..self.hidden {
                w[0][j][i] = p1[i * self.hidden + j].round() as i64;
            }
        }
        for j in 0..self.hidden {
            for o in 0..self.dout {
                w[1][o][j] = p2[j * self.dout + o].round() as i64;
            }
        }
        QuantMlp {
            w,
            b: vec![
                self.b1.iter().map(|&b| b.round() as i64).collect(),
                self.b2.iter().map(|&b| b.round() as i64).collect(),
            ],
            in_bits: reference.in_bits,
            w_scales: reference.w_scales.clone(),
        }
    }
}

/// Area model for Eq. (1): per-input-width multiplier area LUTs (the
/// paper's pre-synthesized LUT, extended to each neuron input size).
pub struct AreaModel {
    luts: std::collections::HashMap<usize, crate::clustering::AreaLut>,
}

impl AreaModel {
    /// Build LUTs for every input width the model's layers use.
    pub fn for_model(q: &QuantMlp, lib: &crate::pdk::EgtLibrary, threads: usize) -> Self {
        let widths = crate::axsum::layer_input_widths(q, &crate::axsum::ShiftPlan::exact(q));
        let mut need: Vec<usize> = widths.iter().flatten().copied().collect();
        need.sort_unstable();
        need.dedup();
        let mut luts = std::collections::HashMap::new();
        for w in need {
            luts.insert(
                w,
                crate::clustering::multiplier_area_lut(w, W_MAX as u64, lib, threads),
            );
        }
        AreaModel { luts }
    }

    pub fn mult_area(&self, a_bits: usize, w: i64) -> f64 {
        // fall back to the closest width we synthesized (widths shift by a
        // bit or two as retraining changes coefficients; the paper keeps a
        // fixed LUT as well)
        let lut = self
            .luts
            .get(&a_bits)
            .or_else(|| {
                self.luts
                    .iter()
                    .min_by_key(|(k, _)| k.abs_diff(a_bits))
                    .map(|(_, v)| v)
            })
            .expect("empty AreaModel");
        lut.area_of(w)
    }

    /// AR(MLP): summed bespoke-multiplier area (Eq. 1), using the fixed
    /// width profile of the reference model.
    pub fn ar(&self, q: &QuantMlp, widths: &[Vec<usize>]) -> f64 {
        let mut total = 0.0;
        for (l, layer) in q.w.iter().enumerate() {
            for row in layer {
                for (i, &w) in row.iter().enumerate() {
                    total += self.mult_area(widths[l][i], w);
                }
            }
        }
        total
    }
}

/// Driver configuration (paper defaults: T user-set, m=10, α=0.8).
#[derive(Clone, Debug)]
pub struct RetrainConfig {
    /// Accuracy-loss threshold T (absolute, e.g. 0.01).
    pub threshold: f64,
    /// Epochs per cluster level (m).
    pub epochs_per_level: usize,
    /// Score weight α.
    pub alpha: f64,
    pub lr0: f32,
    /// Multiplier applied when an epoch updates no coefficient while the
    /// accuracy is still unacceptable ("increase the learning rate").
    pub lr_boost: f32,
    pub batch: usize,
    pub seed: u64,
}

impl Default for RetrainConfig {
    fn default() -> Self {
        RetrainConfig {
            threshold: 0.01,
            epochs_per_level: 10,
            alpha: 0.8,
            lr0: 4.0,
            lr_boost: 2.0,
            batch: 64,
            seed: 0x5EED,
        }
    }
}

/// Per-level log (cluster-consumption reporting, paper §4.1).
#[derive(Clone, Debug)]
pub struct LevelLog {
    pub level: usize,
    pub best_acc: f64,
    pub best_score: f64,
    pub epochs: usize,
    pub lr_boosts: usize,
}

/// Retraining outcome.
#[derive(Clone, Debug)]
pub struct RetrainOutcome {
    pub q: QuantMlp,
    /// Number of cluster groups consumed (1 = only C0).
    pub clusters_used: usize,
    pub acc_train: f64,
    pub acc0_train: f64,
    pub score: f64,
    pub ar0: f64,
    pub ar: f64,
    pub met_threshold: bool,
    pub levels: Vec<LevelLog>,
}

/// Eq. (1).
pub fn score(alpha: f64, acc: f64, acc0: f64, ar: f64, ar0: f64) -> f64 {
    let acc_term = if acc0 > 0.0 { acc / acc0 } else { 0.0 };
    let area_term = if ar0 > 0.0 { (ar0 - ar) / ar0 } else { 0.0 };
    alpha * acc_term + (1.0 - alpha) * area_term
}

/// Algorithm 1.
pub fn printing_friendly_retrain(
    q0: &QuantMlp,
    x_train_int: &[Vec<i64>],
    y_train: &[usize],
    clusters: &Clusters,
    area: &AreaModel,
    cfg: &RetrainConfig,
    backend: &mut dyn TrainBackend,
) -> anyhow::Result<RetrainOutcome> {
    let widths = crate::axsum::layer_input_widths(q0, &crate::axsum::ShiftPlan::exact(q0));
    let acc0 = q0.accuracy_exact(x_train_int, y_train);
    let ar0 = area.ar(q0, &widths);

    let mut best: Option<(QuantMlp, f64, f64, f64, usize)> = None; // (q, score, acc, ar, level)
    let mut best_any: Option<(QuantMlp, f64, f64, f64, usize)> = None; // ignores threshold
    let mut levels: Vec<LevelLog> = Vec::new();

    'levels: for level in 0..clusters.n_clusters() {
        let vc: Vec<f32> = clusters
            .vc_for_level(level)
            .into_iter()
            .map(|v| v as f32)
            .collect();
        // MLP' <- MLP0 (reset per level, per Algorithm 1)
        let mut state = RetrainState::from_quant(
            q0,
            x_train_int,
            y_train,
            cfg.batch,
            cfg.seed ^ (level as u64) << 32,
        );
        let mut lr = cfg.lr0;
        let mut log = LevelLog {
            level,
            best_acc: 0.0,
            best_score: 0.0,
            epochs: 0,
            lr_boosts: 0,
        };
        // epoch 0 candidate: the initial projection of MLP0 onto VC
        let consider = |state: &RetrainState,
                            best: &mut Option<(QuantMlp, f64, f64, f64, usize)>,
                            best_any: &mut Option<(QuantMlp, f64, f64, f64, usize)>,
                            log: &mut LevelLog|
         -> f64 {
            let cand = state.to_quant(&vc, q0);
            let acc = cand.accuracy_exact(x_train_int, y_train);
            let ar = area.ar(&cand, &widths);
            let s = score(cfg.alpha, acc, acc0, ar, ar0);
            if acc > log.best_acc {
                log.best_acc = acc;
            }
            if s > log.best_score {
                log.best_score = s;
            }
            if acc >= acc0 - cfg.threshold - 1e-12
                && best.as_ref().is_none_or(|b| s > b.1)
            {
                *best = Some((cand.clone(), s, acc, ar, level));
            }
            if best_any.as_ref().is_none_or(|b| (acc, s) > (b.2, b.1)) {
                *best_any = Some((cand, s, acc, ar, level));
            }
            acc
        };
        consider(&state, &mut best, &mut best_any, &mut log);

        for _epoch in 0..cfg.epochs_per_level {
            let stats = backend.train_epoch(&mut state, &vc, lr)?;
            log.epochs += 1;
            let acc = consider(&state, &mut best, &mut best_any, &mut log);
            if stats.changed == 0 && acc < acc0 - cfg.threshold {
                lr *= cfg.lr_boost;
                log.lr_boosts += 1;
            }
        }
        let met = log.best_acc >= acc0 - cfg.threshold - 1e-12;
        levels.push(log);
        if met {
            break 'levels;
        }
    }

    let met_threshold = best.is_some();
    let (q, s, acc, ar, level) = best.or(best_any).expect("at least one candidate");
    Ok(RetrainOutcome {
        q,
        clusters_used: level + 1,
        acc_train: acc,
        acc0_train: acc0,
        score: s,
        ar0,
        ar,
        met_threshold,
        levels,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_extremes() {
        // identical model: S = alpha
        assert!((score(0.8, 0.9, 0.9, 100.0, 100.0) - 0.8).abs() < 1e-12);
        // same acc, zero area: S = 1
        assert!((score(0.8, 0.9, 0.9, 0.0, 100.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn projection_tie_breaks_to_first() {
        // 0.5 is equidistant from 0 and 1: first entry wins
        assert_eq!(RetrainState::project_val(0.5, &[0.0, 1.0]), 0.0);
        assert_eq!(RetrainState::project_val(0.5, &[1.0, 0.0]), 1.0);
        assert_eq!(RetrainState::project_val(-3.4, &[0.0, -4.0, 4.0]), -4.0);
    }

    #[test]
    fn state_roundtrip_layout() {
        let q0 = QuantMlp {
            w: vec![
                vec![vec![1, 2, 3], vec![4, 5, 6]],
                vec![vec![7, 8]],
            ],
            b: vec![vec![9, 10], vec![11]],
            in_bits: 4,
            w_scales: vec![1.0, 1.0],
        };
        let xs = vec![vec![1i64, 2, 3]];
        let ys = vec![0usize];
        let st = RetrainState::from_quant(&q0, &xs, &ys, 4, 1);
        // full-range VC: projection is identity
        let vc: Vec<f32> = (-127..=127).map(|v| v as f32).collect();
        let q1 = st.to_quant(&vc, &q0);
        assert_eq!(q0.w, q1.w);
        assert_eq!(q0.b, q1.b);
    }
}

//! # ax-printed-mlp
//!
//! Production-grade reproduction of *"Co-Design of Approximate Multilayer
//! Perceptron for Ultra-Resource Constrained Printed Circuits"* (IEEE TC
//! 2023): an automated HW/SW co-design framework that turns trained MLPs
//! into approximate bespoke printed circuits via printing-friendly
//! coefficient retraining and AxSum summation truncation.
//!
//! Architecture (see README.md and ARCHITECTURE.md at the repository
//! root for the module map, the engine matrix and the data-flow diagram):
//! * **L3 (this crate)** — the co-design coordinator plus the full EDA
//!   substrate (PDK model, netlist synthesis, logic simulation,
//!   area/power/delay estimation, Verilog emission), the retraining
//!   driver, the exhaustive DSE ([`dse::sweep`]) with its sharded
//!   checkpointable orchestration ([`dse::shard`]), the NSGA-II genetic
//!   DSE over per-neuron approximation genomes ([`search`]), the
//!   differential conformance harness ([`conformance`]) pinning every
//!   engine bit-exact, and the baselines \[2\]\[8\]\[15\].
//! * **L2/L1 (python, build-time only)** — JAX model + Pallas AxSum kernel,
//!   AOT-lowered to HLO-text artifacts executed from Rust via PJRT
//!   (`runtime`).

// The crate has zero unsafe; keep that a guarantee, not an accident
// (see ARCHITECTURE.md §Static analysis).
#![forbid(unsafe_code)]

pub mod util;

pub mod analysis;
pub mod axsum;
pub mod baselines;
pub mod battery;
pub mod cli;
pub mod clustering;
pub mod conformance;
pub mod coordinator;
pub mod datasets;
pub mod estimate;
pub mod dse;
pub mod experiments;
pub mod fixed;
pub mod mlp;
pub mod obs;
pub mod retrain;
pub mod runtime;
pub mod netlist;
pub mod pdk;
pub mod report;
pub mod search;
pub mod sim;
pub mod synth;
pub mod verilog;

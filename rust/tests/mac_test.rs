//! Conformance tests for the bespoke-MAC (CSD adder-graph) and
//! approximate-activation families.
//!
//! Layers under test: the CSD recoding itself (decode == exact i64
//! value, canonical digit spacing), the shared-adder-graph netlist
//! backend (sharing must never change a logit), the truncated-ReLU /
//! reduced-precision-argmax reference semantics, and the full
//! differential stack (`axsum` reference vs `FlatEval` vs the bit-sliced
//! planes at every width vs the synthesized netlist) under fuzzed
//! family plans.

use axmlp::axsum::{
    csd_merge, csd_of, csd_topk, csd_value, forward_ax, ActPlan, AxPlan, CsdDigit, FlatEval,
    FlatScratch, MacPlan, MacSpec, ReluSpec, ShiftPlan,
};
use axmlp::conformance::{check_case_all_ax, gen, PlanKind, TopologyRange};
use axmlp::dse::{
    evaluate_design_packed_ax, DseConfig, EngineScratch, EvalBackend, QuantData, SweepStimuli,
};
use axmlp::fixed::QuantMlp;
use axmlp::pdk::EgtLibrary;
use axmlp::util::rng::Rng;

// ---------------------------------------------------------------------------
// CSD recoding: exact decode + canonical form
// ---------------------------------------------------------------------------

#[test]
fn csd_decode_is_exact_for_small_and_edge_weights() {
    let mut rng = Rng::new(0x3AC0);
    let mut ws: Vec<i64> = (-16..=16).collect();
    for _ in 0..200 {
        ws.push(rng.range_i64(-1_000_000, 1_000_000));
    }
    // i64 edge magnitudes: the recoding must not overflow internally
    ws.extend([i64::MAX, -i64::MAX, i64::MIN, 1i64 << 62, -(1i64 << 62)]);
    for &w in &ws {
        let digits = csd_of(w);
        assert_eq!(csd_value(&digits), w as i128, "w={w}");
        // canonical CSD: powers strictly decreasing, no adjacent digits
        for pair in digits.windows(2) {
            assert!(
                pair[0].pow >= pair[1].pow + 2,
                "w={w}: adjacent CSD digits {pair:?}"
            );
        }
        if w == 0 {
            assert!(digits.is_empty());
        }
    }
}

#[test]
fn csd_merge_splits_exactly_and_topk_truncates_msb_first() {
    let mut rng = Rng::new(0x3AC1);
    for _ in 0..200 {
        let w = rng.range_i64(-(1i64 << 40), 1i64 << 40);
        let digits = csd_of(w);
        let (wp, wn) = csd_merge(&digits);
        assert_eq!(wp - wn, w, "w={w}");
        // top-k keeps the most significant digits: the kept value's
        // error is below the first dropped digit's weight
        for m in 0..=digits.len() {
            let kept = csd_topk(w, m);
            assert_eq!(&kept[..], &digits[..m]);
            let err = (w as i128 - csd_value(&kept)).unsigned_abs();
            if m < digits.len() {
                assert!(err < (1u128 << (digits[m].pow + 1)), "w={w} m={m}");
            } else {
                assert_eq!(err, 0);
            }
        }
    }
    // the pinned bound-inflation example: top-1 of 7 rounds UP to 8
    assert_eq!(csd_topk(7, 1), vec![CsdDigit { pow: 3, neg: false }]);
}

// ---------------------------------------------------------------------------
// Approximate activations: reference semantics
// ---------------------------------------------------------------------------

#[test]
fn approximate_relu_is_monotone_bounded_and_exact_at_zero() {
    let mut vals: Vec<i64> = vec![i64::MIN, -5, -1, 0, 1, 2, 3, 63, 64, 127, 255, i64::MAX];
    let mut rng = Rng::new(0xAC7);
    for _ in 0..200 {
        vals.push(rng.range_i64(-100_000, 100_000));
    }
    vals.sort_unstable();
    for drop in 0..=4u8 {
        for cap in [0u8, 4, 8, 62] {
            let spec = ReluSpec { drop, cap };
            let mut prev = i64::MIN;
            for &v in &vals {
                let r = spec.apply(v);
                assert!(r >= prev, "{spec:?} not monotone at v={v}");
                assert!(r >= 0, "{spec:?} negative at v={v}");
                assert!(r <= v.max(0), "{spec:?} exceeds exact ReLU at v={v}");
                prev = r;
            }
        }
    }
    // the exact spec IS max(0, v)
    for &v in &vals {
        assert_eq!(ReluSpec::EXACT.apply(v), v.max(0));
    }
    assert!(ReluSpec::EXACT.is_exact());
    assert!(!ReluSpec { drop: 1, cap: 0 }.is_exact());
}

// ---------------------------------------------------------------------------
// Shared adder graph: sharing must never change a logit
// ---------------------------------------------------------------------------

/// Weights picked so the CSD recodings repeat `(input, pow-gap)` pairs
/// (85 = 1010101₂ alone shares twice); every engine — including the
/// netlist logit backend built on the *shared* adder graph — must agree
/// with the digit-by-digit software reference bit for bit.
#[test]
fn adder_graph_sharing_never_changes_logits() {
    let q = QuantMlp {
        w: vec![
            vec![vec![85, -51, 21], vec![-85, 73, 5], vec![37, -21, 85]],
            vec![vec![51, -21, 9], vec![-9, 85, -37]],
        ],
        b: vec![vec![7, -3, 0], vec![-11, 5]],
        in_bits: 4,
        w_scales: vec![1.0, 1.0],
    };
    let full_csd = |q: &QuantMlp, m: Option<usize>| -> MacPlan {
        let mut mac = MacPlan::shift_only(q);
        for (l, layer) in q.w.iter().enumerate() {
            for (j, row) in layer.iter().enumerate() {
                mac.neurons[l][j] = MacSpec::Csd(
                    row.iter()
                        .map(|&w| m.map_or_else(|| csd_of(w), |m| csd_topk(w, m)))
                        .collect(),
                );
            }
        }
        mac
    };
    let mut rng = Rng::new(0x5AA);
    let xs: Vec<Vec<i64>> = (0..70)
        .map(|_| (0..3).map(|_| rng.range_i64(0, 15)).collect())
        .collect();
    for m in [None, Some(2), Some(1)] {
        let ax = AxPlan {
            shifts: ShiftPlan::exact(&q),
            mac: full_csd(&q, m),
            act: ActPlan::exact(q.n_layers()),
        };
        assert_eq!(
            check_case_all_ax(&q, &ax, &ax, &ax, &xs).map(|f| f.to_string()),
            None,
            "m={m:?}"
        );
    }
}

// ---------------------------------------------------------------------------
// Fuzzed differential sweeps: every engine, every plane width
// ---------------------------------------------------------------------------

/// Forced Mac/Act plan families plus the full random plan mix, each
/// case through all nine engines (`check_case_all_ax` runs the
/// reference, flat, u64-ripple, u64/u128/lanes4 carry-save planes,
/// packed-class, and both netlist backends).
#[test]
fn fuzzed_family_plans_are_bit_identical_across_all_engines() {
    let mut rng = Rng::new(0xD1FF);
    let range = TopologyRange::default();
    for case in 0..40u32 {
        let q = gen::random_quant_mlp(&mut rng, &range);
        // 70 patterns: crosses the 64-wide plane-word boundary
        let xs = gen::mixed_stimulus(&mut rng, &q, 70);
        let ax = match case % 3 {
            0 => gen::plan_of_kind_ax(&mut rng, &q, &xs, PlanKind::Mac),
            1 => gen::plan_of_kind_ax(&mut rng, &q, &xs, PlanKind::Act),
            _ => gen::random_ax_plan(&mut rng, &q, &xs).1,
        };
        if let Some(f) = check_case_all_ax(&q, &ax, &ax, &ax, &xs) {
            panic!("case {case}: {f}");
        }
    }
}

// ---------------------------------------------------------------------------
// DSE point evaluation: accuracy identical across backends
// ---------------------------------------------------------------------------

#[test]
fn design_point_accuracy_is_backend_invariant_for_family_plans() {
    let mut rng = Rng::new(0xBAC6);
    let q = gen::random_quant_mlp(&mut rng, &TopologyRange::default());
    let xs = gen::mixed_stimulus(&mut rng, &q, 150);
    let plan0 = ShiftPlan::exact(&q);
    let ys: Vec<usize> = xs.iter().map(|x| axmlp::axsum::predict(&q, &plan0, x)).collect();
    let data = QuantData {
        x_train: &xs[..100],
        y_train: &ys[..100],
        x_test: &xs[100..],
        y_test: &ys[100..],
    };
    let ax = gen::plan_of_kind_ax(&mut rng, &q, &xs[..100], PlanKind::Mac);
    let lib = EgtLibrary::egt_v1();
    let mut results = Vec::new();
    for backend in [
        EvalBackend::Flat,
        EvalBackend::BitSlice,
        EvalBackend::BitSlice128,
        EvalBackend::BitSlice256,
    ] {
        let cfg = DseConfig {
            backend,
            power_patterns: 70,
            threads: 1,
            verify_circuit: true,
            max_eval: 0,
            ..DseConfig::default()
        };
        let stim = SweepStimuli::prepare(&q, &data, &cfg).unwrap();
        let mut scratch = EngineScratch::new();
        let eval = evaluate_design_packed_ax(
            &q,
            ax.clone(),
            0,
            Vec::new(),
            &data,
            &lib,
            &cfg,
            &stim,
            &mut scratch,
        )
        .unwrap();
        results.push((backend, eval));
    }
    let (b0, first) = &results[0];
    for (b, e) in &results[1..] {
        assert_eq!(e.acc_train, first.acc_train, "{b0:?} vs {b:?}");
        assert_eq!(e.acc_test, first.acc_test, "{b0:?} vs {b:?}");
        assert_eq!(e.costs, first.costs, "{b0:?} vs {b:?}");
    }
}

// ---------------------------------------------------------------------------
// FlatEval under family plans matches the per-sample reference
// ---------------------------------------------------------------------------

#[test]
fn flat_eval_matches_reference_forward_under_family_plans() {
    let mut rng = Rng::new(0xF1A7);
    for _ in 0..10 {
        let q = gen::random_quant_mlp(&mut rng, &TopologyRange::default());
        let xs = gen::mixed_stimulus(&mut rng, &q, 40);
        let (_, ax) = gen::random_ax_plan(&mut rng, &q, &xs);
        let flat = FlatEval::new_ax(&q, &ax);
        let mut fs = FlatScratch::new();
        let mut scratch = Vec::new();
        for x in &xs {
            let want = forward_ax(&q, &ax, x, &mut scratch);
            assert_eq!(flat.forward_into(x, &mut fs), &want[..]);
            assert_eq!(flat.classify(&want), axmlp::axsum::predict_ax(&q, &ax, x));
        }
    }
}

//! Integration tests for the static-analysis layer: property coverage
//! (every generated instance verifies clean), mutation coverage (each
//! injected fault class is rejected with its site named), and the
//! static/dynamic composition contract the conformance harness enforces.

use axmlp::analysis::{self, bounds, verifier, IrConfig};
use axmlp::axsum::ShiftPlan;
use axmlp::conformance::{self, gen, ConformConfig};
use axmlp::util::prop::{check, forall_seeded};

/// Property: every fuzzed `(model, plan)` the conformance generators
/// emit passes the full static pipeline — interval propagation, the
/// axsum/bitslice width cross-checks, netlist structure, and bus widths.
#[test]
fn fuzzed_model_plan_pairs_are_statically_sound() {
    let topo = gen::TopologyRange::default();
    forall_seeded(0x11A7, 60, |rng| {
        let q = gen::random_quant_mlp(rng, &topo);
        let xs = gen::mixed_stimulus(rng, &q, 24);
        let (kind, plan) = gen::random_plan(rng, &q, &xs);
        let diags = analysis::check_model("prop", &q, &plan);
        check(
            diags.is_empty(),
            format!(
                "{} plan statically rejected: {}",
                kind.name(),
                analysis::summarize(&diags, 3)
            ),
        )
    });
}

/// Property: fuzzed raw netlists verify clean with dead logic allowed,
/// and clean under the strict config once swept.
#[test]
fn fuzzed_netlists_verify_clean() {
    forall_seeded(0x11A8, 60, |rng| {
        let (nl, _stim) = gen::random_netlist(rng, 4);
        let raw = verifier::verify_netlist(&nl, &IrConfig { allow_dead: true });
        check(
            raw.is_empty(),
            format!("raw netlist flagged: {}", analysis::summarize(&raw, 3)),
        )?;
        let (swept, _) = nl.sweep();
        let strict = verifier::verify_netlist(&swept, &IrConfig::default());
        check(
            strict.is_empty(),
            format!("swept netlist flagged: {}", analysis::summarize(&strict, 3)),
        )
    });
}

/// Mutation: truncating the gate array of a swept MLP netlist leaves a
/// dangling reference (the last gate is live by construction), and the
/// verifier names the missing net.
#[test]
fn dropped_gate_is_named() {
    let mut rng = axmlp::util::rng::Rng::new(0x11A9);
    let q = gen::random_quant_mlp(&mut rng, &gen::TopologyRange::default());
    let plan = ShiftPlan::exact(&q);
    let mut nl = bounds::build_logit_netlist("mut", &q, &plan);
    let dropped = nl.gates.len() - 1;
    nl.gates.truncate(dropped);
    let diags = verifier::verify_netlist(&nl, &IrConfig { allow_dead: true });
    assert!(
        diags
            .iter()
            .any(|d| d.code == "dangling-net" && d.detail.contains(&format!("net {dropped}"))),
        "dropped gate {dropped} not named: {}",
        analysis::summarize(&diags, 5)
    );
}

/// Mutation: widening or narrowing a logit bus makes the netlist
/// disagree with the interval bounds, and the diagnostic carries the
/// neuron's original coordinates.
#[test]
fn resized_logit_bus_is_named() {
    let mut rng = axmlp::util::rng::Rng::new(0x11AA);
    let q = gen::random_quant_mlp(&mut rng, &gen::TopologyRange::default());
    let plan = ShiftPlan::exact(&q);
    let b = bounds::propagate(&q, &plan).expect("generated model propagates");
    let last = q.n_layers() - 1;
    for narrow in [true, false] {
        let mut nl = bounds::build_logit_netlist("mut", &q, &plan);
        let bus = nl
            .outputs
            .iter_mut()
            .find(|bus| bus.name == "logit0")
            .expect("logit0 bus");
        if narrow {
            bus.nets.pop();
        } else {
            let dup = *bus.nets.last().expect("non-empty bus");
            bus.nets.push(dup);
        }
        let diags = bounds::netlist_width_diags("mut", &q, &b, &nl);
        let site = format!("L{last}/N0");
        assert!(
            diags.iter().any(|d| d.code == "bus-width" && d.site.contains(&site)),
            "{} bus not flagged at {site}: {}",
            if narrow { "narrowed" } else { "widened" },
            analysis::summarize(&diags, 5)
        );
    }
}

/// Mutation property: whenever a corrupted shift moves any bound at all,
/// the first diverging neuron is exactly the corrupted one —
/// misattribution would send a debugging session to the wrong neuron.
#[test]
fn corrupted_shift_divergence_is_attributed() {
    let topo = gen::TopologyRange::default();
    forall_seeded(0x11AB, 40, |rng| {
        let q = gen::random_quant_mlp(rng, &topo);
        let xs = gen::mixed_stimulus(rng, &q, 24);
        let (_, plan) = gen::random_plan(rng, &q, &xs);
        let Some((corrupt, (l, j, _))) = gen::corrupt_one_shift(&q, &plan) else {
            return Ok(()); // all-zero weights: nothing to corrupt
        };
        let honest = bounds::propagate(&q, &plan).map_err(|d| analysis::summarize(&d, 3))?;
        let Ok(tampered) = bounds::propagate(&q, &corrupt) else {
            return Ok(()); // corruption may push a bound over i64 — also a catch
        };
        match bounds::first_divergence(&honest, &tampered) {
            // bound-invisible corruption (shift landed past the
            // product's trailing zeros): nothing for the interval pass
            // to see, the dynamic engines own that case
            None => Ok(()),
            Some((dl, dj)) => check(
                (dl, dj) == (l, j),
                format!("corrupted L{l}/N{j} but bounds diverge first at L{dl}/N{dj}"),
            ),
        }
    });
}

/// The analyzer's own canary across several seeds: both injected fault
/// classes caught, sites named.
#[test]
fn analysis_canary_fires_across_seeds() {
    for seed in [2023u64, 7, 0xC0FFEE] {
        let msg = analysis::analysis_canary(seed).expect("canary must fire");
        assert!(msg.contains("dangling net flagged"), "seed {seed}: {msg}");
        assert!(msg.contains("corrupted shift flagged at L"), "seed {seed}: {msg}");
    }
}

/// Static/dynamic composition on a real fuzz run: no generated case may
/// be statically rejected, and no statically-accepted case may mismatch
/// dynamically (the acceptance contract `repro conform` enforces at 256
/// cases; kept smaller here for test-suite latency).
#[test]
fn fuzz_run_has_no_static_dynamic_gap() {
    let report = conformance::run_fuzz(&ConformConfig {
        cases: 48,
        seed: 0x11AC,
        ..Default::default()
    });
    assert!(
        report.static_rejects.is_empty(),
        "static rejects: {:?}",
        report.static_rejects
    );
    assert!(
        report.static_unsound.is_empty(),
        "static-accept + dynamic-mismatch cases: {:?}",
        report.static_unsound
    );
    assert!(report.ok(), "fuzz mismatches: {}", report.mismatches.len());
}

/// The source linter accepts the shipped tree (the same gate CI runs via
/// `repro lint`), and the lexer's allow bookkeeping is visible in the
/// report.
#[test]
fn shipped_tree_is_lint_clean() {
    let rep = analysis::lint_source_tree().expect("walk rust/src");
    assert!(rep.files > 40, "walked only {} files", rep.files);
    assert!(
        rep.violations.is_empty(),
        "source violations:\n{}",
        rep.violations
            .iter()
            .map(std::string::ToString::to_string)
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(rep.allowed >= 6, "expected the marked allow sites, saw {}", rep.allowed);
}

//! Integration tests for the NSGA-II genetic DSE (`search`): grid-seeded
//! runs must be provably no worse than the grid sweep at any accuracy
//! floor, bit-deterministic in the seed, and pluggable into the
//! coordinator as a drop-in strategy.

use axmlp::axsum::{mean_activations, significance};
use axmlp::coordinator::{run_dataset, train_mlp0, DseStrategy, PipelineConfig, SharedContext};
use axmlp::datasets;
use axmlp::dse::{self, DseConfig, QuantData};
use axmlp::fixed::{quantize, quantize_inputs};
use axmlp::mlp::train::TrainConfig;
use axmlp::pdk::EgtLibrary;
use axmlp::retrain::backend_rust::RustBackend;
use axmlp::retrain::RetrainConfig;
use axmlp::search::{nsga2, seed_genomes_from_grid, SearchConfig, SearchSpace};

/// Small quantized model + integer data splits for the search tests.
fn setup(
    key: &str,
    seed: u64,
) -> (
    axmlp::fixed::QuantMlp,
    Vec<Vec<i64>>,
    Vec<usize>,
    Vec<Vec<i64>>,
    Vec<usize>,
) {
    let ds = datasets::load(key, seed).expect("dataset");
    let tcfg = TrainConfig {
        epochs: 40,
        ..Default::default()
    };
    let q0 = quantize(&train_mlp0(&ds, &tcfg, seed));
    (
        q0,
        quantize_inputs(&ds.x_train),
        ds.y_train.clone(),
        quantize_inputs(&ds.x_test),
        ds.y_test.clone(),
    )
}

fn tiny_dse() -> DseConfig {
    DseConfig {
        max_g_levels: 3,
        power_patterns: 32,
        threads: 4,
        verify_circuit: false,
        max_eval: 200,
        ..DseConfig::default()
    }
}

#[test]
fn grid_seeded_search_never_worse_than_grid() {
    let (q0, xt, yt, xe, ye) = setup("ma", 11);
    let data = QuantData {
        x_train: &xt,
        y_train: &yt,
        x_test: &xe,
        y_test: &ye,
    };
    let cfg = tiny_dse();
    let lib = EgtLibrary::egt_v1();
    let means = mean_activations(&q0, &xt);
    let sig = significance(&q0, &means);
    let grid = dse::sweep(&q0, &sig, &data, &lib, &cfg).unwrap();

    let scfg = SearchConfig {
        seed: 3,
        pop_size: 12,
        generations: 4,
        ..Default::default()
    };
    // `lossless` raises the level cap to the fan-in → exact grid encoding
    let space = SearchSpace::lossless(&q0, &sig, scfg.max_levels);
    let seeds = seed_genomes_from_grid(&space, &q0, &grid);
    assert_eq!(seeds.len(), grid.len(), "every grid point seeds the GA");
    let out = nsga2(&q0, &sig, &data, &lib, &cfg, &scfg, &space, &seeds).unwrap();

    // the archive covers every seed evaluation, so at every accuracy
    // floor the genetic pick is at least as small as the grid pick
    let acc_max = grid.iter().map(|d| d.acc_train).fold(0.0f64, f64::max);
    for loss in [0.0, 0.01, 0.02, 0.05, 0.10] {
        let floor = acc_max - loss;
        let gb = dse::best_under_floor(&grid, floor).expect("grid pick");
        let ab = dse::best_under_floor(&out.archive, floor).expect("ga pick");
        assert!(
            ab.costs.area_mm2 <= gb.costs.area_mm2 + 1e-12,
            "floor {floor}: ga {} > grid {}",
            ab.costs.area_mm2,
            gb.costs.area_mm2
        );
        assert!(ab.acc_train >= floor - 1e-12);
    }
    // per-generation log is complete and the front never shrinks to zero
    assert_eq!(out.gens.len(), scfg.generations + 1);
    for g in &out.gens {
        assert!(g.front_size > 0);
        assert!(g.hypervolume.is_finite() && g.hypervolume >= 0.0);
        assert!(g.min_area_mm2.is_finite());
    }
    // the request/memo bookkeeping adds up
    assert_eq!(out.archive.len() + out.memo_hits, out.requested);
}

#[test]
fn nsga2_same_seed_same_front_grid_seeded() {
    let (q0, xt, yt, xe, ye) = setup("v2", 5);
    let data = QuantData {
        x_train: &xt,
        y_train: &yt,
        x_test: &xe,
        y_test: &ye,
    };
    let cfg = tiny_dse();
    let lib = EgtLibrary::egt_v1();
    let means = mean_activations(&q0, &xt);
    let sig = significance(&q0, &means);
    let grid = dse::sweep(&q0, &sig, &data, &lib, &cfg).unwrap();
    let scfg = SearchConfig {
        seed: 42,
        pop_size: 10,
        generations: 3,
        ..Default::default()
    };
    let space = SearchSpace::lossless(&q0, &sig, scfg.max_levels);
    let seeds = seed_genomes_from_grid(&space, &q0, &grid);

    let a = nsga2(&q0, &sig, &data, &lib, &cfg, &scfg, &space, &seeds).unwrap();
    let b = nsga2(&q0, &sig, &data, &lib, &cfg, &scfg, &space, &seeds).unwrap();
    assert_eq!(a.front, b.front);
    assert_eq!(a.requested, b.requested);
    assert_eq!(a.memo_hits, b.memo_hits);
    let fa = a.front_evals();
    let fb = b.front_evals();
    assert_eq!(fa.len(), fb.len());
    for (x, y) in fa.iter().zip(&fb) {
        assert_eq!(x.plan, y.plan);
        assert_eq!(x.acc_train, y.acc_train);
        assert_eq!(x.acc_test, y.acc_test);
        assert_eq!(x.costs, y.costs);
    }
    // a different seed explores a different trajectory (same archive
    // prefix from the seeds, but different random fill / offspring)
    let scfg2 = SearchConfig { seed: 43, ..scfg };
    let c = nsga2(&q0, &sig, &data, &lib, &cfg, &scfg2, &space, &seeds).unwrap();
    assert!(
        c.requested == a.requested,
        "request budget is seed-independent"
    );
}

#[test]
fn pipeline_genetic_strategy_never_worse_than_grid() {
    let ds = datasets::load("ma", 7).expect("dataset");
    let base = PipelineConfig {
        thresholds: vec![0.05],
        dse: DseConfig {
            max_g_levels: 3,
            power_patterns: 48,
            threads: 4,
            verify_circuit: false,
            max_eval: 0,
            ..DseConfig::default()
        },
        retrain: RetrainConfig {
            epochs_per_level: 3,
            ..Default::default()
        },
        train: TrainConfig {
            epochs: 40,
            ..Default::default()
        },
        ..Default::default()
    };
    let genetic = PipelineConfig {
        strategy: DseStrategy::Genetic(SearchConfig {
            seed: 2023,
            pop_size: 10,
            generations: 2,
            ..Default::default()
        }),
        ..base.clone()
    };
    let ctx = SharedContext::new();
    let mut be = RustBackend;
    let grid_out = run_dataset(&ds, &base, &ctx, &mut be).unwrap();
    let mut be2 = RustBackend;
    let ga_out = run_dataset(&ds, &genetic, &ctx, &mut be2).unwrap();

    // same seeds → same retrained model → the genetic pool is a superset
    // of the grid pool, so the chosen design can only get smaller
    let g = &grid_out.thresholds[0];
    let a = &ga_out.thresholds[0];
    assert_eq!(g.retrain_acc_train, a.retrain_acc_train, "retrain differs");
    assert!(
        a.design.costs.area_mm2 <= g.design.costs.area_mm2 + 1e-12,
        "genetic {} worse than grid {}",
        a.design.costs.area_mm2,
        g.design.costs.area_mm2
    );
    assert!(a.area_gain >= g.area_gain - 1e-9);
    // the budget is still respected on the train split
    assert!(
        a.design.acc_train >= ga_out.q0_acc_train - 0.05 - 1e-9,
        "{} vs {}",
        a.design.acc_train,
        ga_out.q0_acc_train
    );
}

#[test]
fn encode_grid_point_roundtrips_on_random_models() {
    // ISSUE 3 satellite: the lossless-seeding claim from PR 2 holds on
    // *random* topologies (1–3 layers, sparse zero weights, varying
    // input precision), not just the shipped datasets: encoding a grid
    // point and decoding the genome reproduces `derive_shifts`' plan
    // bit-for-bit.
    use axmlp::axsum::{derive_shifts, threshold_candidates};
    use axmlp::conformance::gen::{self, TopologyRange};
    use axmlp::util::prop::forall_seeded;

    forall_seeded(0xE2C0DE, 30, |rng| {
        let q = gen::random_quant_mlp(rng, &TopologyRange::default());
        let xs = gen::mixed_stimulus(rng, &q, 40);
        let sig = gen::significance_of(&q, &xs);
        let space = SearchSpace::lossless(&q, &sig, 16);
        for k in 1..=3u32 {
            // thresholds from the grid's own candidate tables, plus the
            // disable sentinel and a saturating value
            let mut gs: Vec<Vec<f64>> = vec![vec![-1.0; q.n_layers()], vec![1e18; q.n_layers()]];
            let mixed: Vec<f64> = (0..q.n_layers())
                .map(|l| {
                    let c = threshold_candidates(&sig, l, 6);
                    c[rng.below(c.len())]
                })
                .collect();
            gs.push(mixed);
            for g in &gs {
                let genome = space.encode_grid_point(k, g);
                let decoded = space.decode(&q, &sig, &genome);
                let derived = derive_shifts(&q, &sig, g, k);
                if decoded != derived {
                    return Err(format!(
                        "genome decode diverged from derive_shifts (k={k}, g={g:?}, din={}, layers={})",
                        q.din(),
                        q.n_layers()
                    ));
                }
            }
        }
        Ok(())
    });
}

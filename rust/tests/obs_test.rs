//! Observability integration: span aggregation across pool workers,
//! per-run counter windows, the on-disk metrics.json schema, and the
//! results-neutrality guarantee — the sweep is bit-identical with
//! telemetry on or off.

use axmlp::axsum::{self, mean_activations, significance, ShiftPlan, Significance};
use axmlp::dse::shard::first_divergence;
use axmlp::dse::{self, DseConfig, EvalBackend, QuantData};
use axmlp::fixed::QuantMlp;
use axmlp::obs;
use axmlp::pdk::EgtLibrary;
use axmlp::util::json::Json;
use axmlp::util::pool;
use axmlp::util::rng::Rng;

use std::sync::{Mutex, MutexGuard, OnceLock};

/// The obs registry is process-global; tests toggling it must not
/// interleave. (The lib unit tests hold their own lock in a separate
/// test process, so the two suites cannot race each other.)
fn lock() -> MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

/// Self-labeled toy model (exact forward generates the labels, so the
/// exact design point scores 1.0 and truncation trades accuracy).
fn toy(seed: u64) -> (QuantMlp, Vec<Vec<i64>>, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let q = QuantMlp {
        w: vec![
            (0..3)
                .map(|_| (0..4).map(|_| rng.range_i64(-90, 90)).collect())
                .collect(),
            (0..3)
                .map(|_| (0..3).map(|_| rng.range_i64(-90, 90)).collect())
                .collect(),
        ],
        b: vec![
            (0..3).map(|_| rng.range_i64(-40, 40)).collect(),
            (0..3).map(|_| rng.range_i64(-40, 40)).collect(),
        ],
        in_bits: 4,
        w_scales: vec![1.0, 1.0],
    };
    let xs: Vec<Vec<i64>> = (0..180)
        .map(|_| (0..4).map(|_| rng.range_i64(0, 15)).collect())
        .collect();
    let plan = ShiftPlan::exact(&q);
    let ys: Vec<usize> = xs.iter().map(|x| axsum::predict(&q, &plan, x)).collect();
    (q, xs, ys)
}

fn sig_of(q: &QuantMlp, xs: &[Vec<i64>]) -> Significance {
    significance(q, &mean_activations(q, xs))
}

#[test]
fn span_tree_merges_pool_worker_spans_under_the_caller() {
    let _l = lock();
    obs::set_enabled(true);
    obs::reset_all();
    let items: Vec<u64> = (0..64).collect();
    let out = {
        let _outer = obs::span("obsit.outer");
        pool::parallel_map(&items, 4, |&x| {
            let _s = obs::span("obsit.item");
            // enough work that the span duration cannot round to zero
            let mut acc = x;
            for i in 0..5_000u64 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            std::hint::black_box(acc)
        })
    };
    obs::set_enabled(false);
    assert_eq!(out.len(), 64);
    let rows = obs::span_rows();
    let find = |p: &str| rows.iter().find(|(k, _)| k == p).map(|(_, s)| s.clone());
    // deterministic count, nondeterministic-but-positive nanos
    let item = find("obsit.outer/obsit.item").expect("worker spans nest under the caller");
    assert_eq!(item.count, 64);
    assert!(item.total_ns > 0);
    assert!(item.min_ns <= item.max_ns);
    assert_eq!(find("obsit.outer").expect("outer span").count, 1);
    // the worker threads are gone: no orphan `obsit.item` root node
    assert!(find("obsit.item").is_none());
}

#[test]
fn begin_run_windows_counters_without_losing_totals() {
    let _l = lock();
    obs::counters::DEDUP_FANOUT.add(4);
    obs::begin_run();
    assert_eq!(obs::run_value("dse.dedup_fanout"), 0);
    obs::counters::DEDUP_FANOUT.add(2);
    assert_eq!(obs::run_value("dse.dedup_fanout"), 2);
    assert!(obs::counters::DEDUP_FANOUT.total() >= 6);
    obs::begin_run();
    assert_eq!(obs::run_value("dse.dedup_fanout"), 0);
}

#[test]
fn write_metrics_emits_the_stable_schema_on_disk() {
    let _l = lock();
    obs::set_enabled(true);
    obs::reset_all();
    {
        let _s = obs::span("obsit.write");
    }
    obs::gauge_set("obsit.gauge", 1.25);
    let path = std::env::temp_dir().join(format!("axmlp_obs_test_{}.json", std::process::id()));
    obs::write_metrics(&path).unwrap();
    obs::set_enabled(false);
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let j = Json::parse(&text).unwrap();
    assert_eq!(j.req_f64("version").unwrap(), 1.0);
    let named = |arr: &str, key: &str, want: &str| {
        j.get(arr)
            .and_then(Json::as_arr)
            .map(|rows| {
                rows.iter()
                    .any(|r| r.get(key).and_then(Json::as_str) == Some(want))
            })
            .unwrap_or(false)
    };
    assert!(named("spans", "path", "obsit.write"));
    assert!(named("gauges", "name", "obsit.gauge"));
    // every registered counter row is present with value and total
    for (name, _, _) in obs::counter_rows() {
        assert!(named("counters", "name", name), "missing counter {name}");
    }
    for hist in ["dse.eval_point_ns", "stream.flush_ns", "shard.claim_wait_ns"] {
        assert!(named("histograms", "name", hist), "missing histogram {hist}");
    }
}

#[test]
fn resume_does_not_replay_persisted_eval_ns_into_the_histogram() {
    // shard checkpoints persist per-shard eval_ns for reporting; a
    // resumed (pure-load) pass must NOT re-feed those nanoseconds into
    // the live dse.eval_point_ns histogram — only real evaluations
    // record samples, or resumed runs would double-count their history
    let _l = lock();
    use axmlp::dse::shard::{sweep_sharded, ShardConfig};
    let (q, xs, ys) = toy(77);
    let data = QuantData {
        x_train: &xs[..130],
        y_train: &ys[..130],
        x_test: &xs[130..],
        y_test: &ys[130..],
    };
    let sig = sig_of(&q, data.x_train);
    let lib = EgtLibrary::egt_v1();
    let cfg = DseConfig {
        max_g_levels: 3,
        power_patterns: 24,
        threads: 4,
        verify_circuit: false,
        max_eval: 0,
        backend: EvalBackend::Flat,
    };
    let dir = std::env::temp_dir().join(format!("axmlp_obs_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let scfg = ShardConfig {
        shards: 2,
        checkpoint_dir: Some(dir.clone()),
        resume: false,
        stop_after: None,
        claim: None,
    };
    obs::set_enabled(true);
    obs::reset_all();
    sweep_sharded(&q, &sig, &data, &lib, &cfg, &scfg).unwrap();
    let count_of = || {
        obs::hist_rows()
            .iter()
            .find(|(n, _)| *n == "dse.eval_point_ns")
            .map_or(0, |(_, s)| s.count)
    };
    let c1 = count_of();
    assert!(c1 > 0, "the fresh pass records eval samples");

    let rcfg = ShardConfig {
        resume: true,
        ..scfg
    };
    let rep = sweep_sharded(&q, &sig, &data, &lib, &cfg, &rcfg).unwrap();
    obs::set_enabled(false);
    assert_eq!(rep.shards_evaluated, 0, "resume pass is a pure load");
    assert_eq!(
        count_of(),
        c1,
        "resume replayed persisted eval_ns into the live histogram"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sweep_is_bit_identical_with_telemetry_on_and_off() {
    let _l = lock();
    let (q, xs, ys) = toy(2023);
    let data = QuantData {
        x_train: &xs[..130],
        y_train: &ys[..130],
        x_test: &xs[130..],
        y_test: &ys[130..],
    };
    let sig = sig_of(&q, data.x_train);
    let lib = EgtLibrary::egt_v1();
    let cfg = DseConfig {
        max_g_levels: 3,
        power_patterns: 24,
        threads: 4,
        verify_circuit: false,
        max_eval: 0,
        backend: EvalBackend::BitSlice,
    };
    obs::set_enabled(false);
    let off = dse::sweep(&q, &sig, &data, &lib, &cfg).unwrap();
    obs::set_enabled(true);
    obs::reset_all();
    let on = dse::sweep(&q, &sig, &data, &lib, &cfg).unwrap();
    obs::set_enabled(false);
    if let Some((p, field, detail)) = first_divergence(&off, &on) {
        panic!("telemetry changed sweep results at point {p} ({field}): {detail}");
    }
    // and the instrumented run actually recorded its instruments: one
    // histogram sample per deduped representative (≤ grid points)
    assert!(obs::span_rows().iter().any(|(p, _)| p == "dse.sweep"));
    let hists = obs::hist_rows();
    let eval = hists
        .iter()
        .find(|(n, _)| *n == "dse.eval_point_ns")
        .expect("eval histogram registered");
    assert!(eval.1.count > 0 && eval.1.count <= off.len() as u64);
    assert!(eval.1.sum_ns > 0);
}

//! Property tests for the bit-sliced forward engines (ISSUE 4 tentpole,
//! widened in ISSUE 6): `BitSliceEval` must be *bit-exact* against
//! `axsum::forward` and `FlatEval::forward_batch` on fuzzed models and
//! plans of every decoder family — at every plane width (u64, u128,
//! `Lanes4`) under both ripple and carry-save accumulation, across the
//! 64/128/256-pattern chunk edges and the adversarial stimulus corners
//! (all-zero / all-saturated inputs, all-saturated weights) — plus the
//! end-to-end guarantee that a DSE point under any bitslice backend
//! reproduces the flat backend's evaluation exactly.

use axmlp::axsum::{self, AccumMode, BitSliceEval, BitSliceScratch, FlatEval, FlatScratch};
use axmlp::conformance::gen::{self, PlanKind, TopologyRange};
use axmlp::dse::{evaluate_design, DseConfig, EvalBackend, QuantData};
use axmlp::fixed::QuantMlp;
use axmlp::pdk::EgtLibrary;
use axmlp::sim::{Lanes4, PackedStimulus};
use axmlp::util::rng::Rng;

/// Every (plane width, accumulation mode) combination must reproduce
/// `want` exactly on `packed`.
fn assert_all_widths(bs: &BitSliceEval, packed: &PackedStimulus, want: &[i64], ctx: &str) {
    let mut s64 = BitSliceScratch::<u64>::new();
    let mut s128 = BitSliceScratch::<u128>::new();
    let mut s256 = BitSliceScratch::<Lanes4>::new();
    let mut got = Vec::new();
    for accum in [AccumMode::Ripple, AccumMode::CarrySave] {
        bs.forward_packed_w(packed, &mut got, &mut s64, accum);
        assert_eq!(got, want, "{ctx} u64/{accum:?}");
        bs.forward_packed_w(packed, &mut got, &mut s128, accum);
        assert_eq!(got, want, "{ctx} u128/{accum:?}");
        bs.forward_packed_w(packed, &mut got, &mut s256, accum);
        assert_eq!(got, want, "{ctx} lanes4/{accum:?}");
    }
}

#[test]
fn bitslice_logits_match_reference_on_fuzzed_models_all_plan_families() {
    let mut rng = Rng::new(0xB5);
    let mut scratch = Vec::new();
    // chunk-edge pattern counts for every plane width: the packer's (and
    // widened gatherer's) boundary handling is the likeliest divergence
    const TOTALS: [usize; 11] = [63, 64, 65, 127, 128, 129, 255, 256, 257, 1, 40];
    for case in 0..33 {
        let q = gen::random_quant_mlp(&mut rng, &TopologyRange::default());
        let total = TOTALS[case % TOTALS.len()];
        let xs = gen::mixed_stimulus(&mut rng, &q, total);
        let kind = PlanKind::ALL[case % PlanKind::ALL.len()];
        let plan = gen::plan_of_kind(&mut rng, &q, &xs, kind);

        let flat = FlatEval::new(&q, &plan);
        let mut fs = FlatScratch::new();
        let mut want = Vec::new();
        flat.forward_batch(&xs, &mut want, &mut fs);

        let bs = BitSliceEval::new(&q, &plan).unwrap();
        let mut bss = BitSliceScratch::new();
        let mut got = Vec::new();
        bs.forward_batch(&xs, &mut got, &mut bss);
        assert_eq!(got, want, "case {case} ({}, {total} patterns)", kind.name());

        // the widened planes and carry-save accumulation over the same
        // packed stimulus must agree bit-for-bit
        let packed = PackedStimulus::from_features(&xs, q.din(), q.in_bits).unwrap();
        assert_all_widths(&bs, &packed, &want, &format!("case {case} ({total} patterns)"));

        // spot-check against the per-sample reference forward too
        let dout = q.dout();
        for (p, x) in xs.iter().enumerate().take(5) {
            let r = axsum::forward(&q, &plan, x, &mut scratch);
            assert_eq!(&got[p * dout..(p + 1) * dout], &r[..], "case {case} pattern {p}");
        }
    }
}

#[test]
fn bitslice_forward_packed_shares_the_simulator_transpose() {
    // the packed entry point consumes the exact PackedStimulus the
    // netlist simulator uses — one transpose, two engines
    let mut rng = Rng::new(0xB6);
    let q = gen::random_quant_mlp(&mut rng, &TopologyRange::default());
    let xs = gen::mixed_stimulus(&mut rng, &q, 65);
    let plan = gen::plan_of_kind(&mut rng, &q, &xs, PlanKind::RandomShifts);
    let packed = PackedStimulus::from_features(&xs, q.din(), q.in_bits).unwrap();

    let bs = BitSliceEval::new(&q, &plan).unwrap();
    let mut bss = BitSliceScratch::new();
    let mut via_packed = Vec::new();
    bs.forward_packed(&packed, &mut via_packed, &mut bss);
    let mut via_rows = Vec::new();
    bs.forward_batch(&xs, &mut via_rows, &mut bss);
    assert_eq!(via_packed, via_rows);
}

#[test]
fn bitslice_accuracy_matches_flat_on_fuzzed_labels_all_widths() {
    let mut rng = Rng::new(0xB7);
    for round in 0..12 {
        let q = gen::random_quant_mlp(&mut rng, &TopologyRange::default());
        let total = [127usize, 128, 129, 255, 256, 257][round % 6];
        let xs = gen::mixed_stimulus(&mut rng, &q, total);
        let plan = gen::plan_of_kind(&mut rng, &q, &xs, PlanKind::Grid);
        // random labels, deliberately including out-of-range classes
        let ys: Vec<usize> = (0..xs.len()).map(|_| rng.below(q.dout() + 2)).collect();
        let flat = FlatEval::new(&q, &plan);
        let mut fs = FlatScratch::new();
        let want = flat.accuracy_with(&xs, &ys, &mut fs);
        let bs = BitSliceEval::new(&q, &plan).unwrap();
        let mut bss = BitSliceScratch::new();
        assert_eq!(bs.accuracy_with(&xs, &ys, &mut bss), want);

        let packed = PackedStimulus::from_features(&xs, q.din(), q.in_bits).unwrap();
        let mut s128 = BitSliceScratch::<u128>::new();
        let mut s256 = BitSliceScratch::<Lanes4>::new();
        assert_eq!(
            bs.accuracy_packed_w(&packed, &ys, &mut s128, AccumMode::CarrySave),
            want,
            "u128 round {round}"
        );
        assert_eq!(
            bs.accuracy_packed_w(&packed, &ys, &mut s256, AccumMode::CarrySave),
            want,
            "lanes4 round {round}"
        );
        // and the chunk-parallel path
        assert_eq!(
            bs.accuracy_packed_par::<Lanes4>(&packed, &ys, 3, AccumMode::CarrySave),
            want,
            "lanes4 parallel round {round}"
        );
    }
}

#[test]
fn all_saturated_stimulus_matches_at_chunk_edges() {
    // every input at 2^in_bits - 1 maximizes carry depth in the sliced
    // adders — the worst case for ripple *and* for the deferred
    // carry-save merge
    let mut rng = Rng::new(0xB8);
    let q = gen::random_quant_mlp(&mut rng, &TopologyRange::default());
    let a_max = (1i64 << q.in_bits) - 1;
    for total in [63usize, 64, 65, 127, 128, 129, 255, 256, 257] {
        let xs: Vec<Vec<i64>> = (0..total).map(|_| vec![a_max; q.din()]).collect();
        let plan = gen::plan_of_kind(&mut rng, &q, &xs, PlanKind::RandomShifts);
        let flat = FlatEval::new(&q, &plan);
        let mut fs = FlatScratch::new();
        let mut want = Vec::new();
        flat.forward_batch(&xs, &mut want, &mut fs);
        let bs = BitSliceEval::new(&q, &plan).unwrap();
        let mut bss = BitSliceScratch::new();
        let mut got = Vec::new();
        bs.forward_batch(&xs, &mut got, &mut bss);
        assert_eq!(got, want, "{total} saturated patterns");
        let packed = PackedStimulus::from_features(&xs, q.din(), q.in_bits).unwrap();
        assert_all_widths(&bs, &packed, &want, &format!("{total} saturated patterns"));
    }
}

#[test]
fn all_saturated_weights_match_across_widths() {
    // weights pinned to the quantized extremes (+127 / -127) drive every
    // accumulator to its compile-time bound — the corner where a
    // carry-save plane-count error or a widened-gather masking bug would
    // surface first
    let mut rng = Rng::new(0xBA);
    for round in 0..4 {
        let mut q = gen::random_quant_mlp(&mut rng, &TopologyRange::default());
        let mut flip = round % 2 == 0;
        for layer in &mut q.w {
            for row in layer.iter_mut() {
                for w in row.iter_mut() {
                    *w = if flip { 127 } else { -127 };
                    flip = !flip;
                }
            }
        }
        let xs = gen::mixed_stimulus(&mut rng, &q, 129);
        let plan = gen::plan_of_kind(&mut rng, &q, &xs, PlanKind::ALL[round % PlanKind::ALL.len()]);
        let flat = FlatEval::new(&q, &plan);
        let mut fs = FlatScratch::new();
        let mut want = Vec::new();
        flat.forward_batch(&xs, &mut want, &mut fs);
        let bs = BitSliceEval::new(&q, &plan).unwrap();
        let packed = PackedStimulus::from_features(&xs, q.din(), q.in_bits).unwrap();
        assert_all_widths(&bs, &packed, &want, &format!("saturated weights round {round}"));
    }
}

#[test]
fn dse_point_under_every_bitslice_backend_is_bit_identical() {
    // evaluate_design dispatches on DseConfig::backend; all backends
    // must produce the same DesignEval for the same point (accuracy from
    // different engines, costs from the same netlist simulation)
    let mut rng = Rng::new(0xB9);
    let q = gen::random_quant_mlp(
        &mut rng,
        &TopologyRange {
            layers: (2, 2),
            din: (4, 6),
            dim: (2, 4),
            ..TopologyRange::default()
        },
    );
    let xs = gen::mixed_stimulus(&mut rng, &q, 160);
    let plan0 = axsum::ShiftPlan::exact(&q);
    let ys: Vec<usize> = xs.iter().map(|x| axsum::predict(&q, &plan0, x)).collect();
    let data = QuantData {
        x_train: &xs[..100],
        y_train: &ys[..100],
        x_test: &xs[100..],
        y_test: &ys[100..],
    };
    let plan = gen::plan_of_kind(&mut rng, &q, &xs, PlanKind::Grid);
    let lib = EgtLibrary::egt_v1();
    let mut cfg = DseConfig {
        max_g_levels: 3,
        power_patterns: 70,
        threads: 2,
        verify_circuit: true,
        max_eval: 0,
        ..DseConfig::default()
    };
    let a = evaluate_design(&q, plan.clone(), 2, vec![0.0; q.n_layers()], &data, &lib, &cfg)
        .unwrap();
    for backend in [
        EvalBackend::BitSlice,
        EvalBackend::BitSlice128,
        EvalBackend::BitSlice256,
    ] {
        cfg.backend = backend;
        let b = evaluate_design(
            &q,
            plan.clone(),
            2,
            vec![0.0; q.n_layers()],
            &data,
            &lib,
            &cfg,
        )
        .unwrap();
        assert_eq!(a.acc_train, b.acc_train, "{}", backend.name());
        assert_eq!(a.acc_test, b.acc_test, "{}", backend.name());
        assert_eq!(a.costs, b.costs, "{}", backend.name());
        assert_eq!(a.plan, b.plan, "{}", backend.name());
    }
}

#[test]
fn plan_compile_rejection_propagates_as_contextful_error() {
    // a 60-bit input bus times a 127 weight overflows the i64 product
    // bound: the DSE point must surface a Result naming the rejection,
    // not panic inside the engine (the old `assert!(width <= 63)` path)
    let q = QuantMlp {
        w: vec![vec![vec![127, 127], vec![-127, 127]]],
        b: vec![vec![0, 0]],
        in_bits: 60,
        w_scales: vec![1.0],
    };
    let plan = axsum::ShiftPlan::exact(&q);
    let xs: Vec<Vec<i64>> = (0..8).map(|i| vec![i as i64, (i * 3) as i64]).collect();
    let ys: Vec<usize> = (0..8).map(|i| i % 2).collect();
    let data = QuantData {
        x_train: &xs[..6],
        y_train: &ys[..6],
        x_test: &xs[6..],
        y_test: &ys[6..],
    };
    let lib = EgtLibrary::egt_v1();
    let cfg = DseConfig {
        verify_circuit: false,
        power_patterns: 16,
        backend: EvalBackend::BitSlice256,
        ..DseConfig::default()
    };
    let err = evaluate_design(&q, plan, 2, vec![0.0], &data, &lib, &cfg).unwrap_err();
    assert!(err.contains("rejected"), "{err}");
    assert!(err.contains("overflows i64"), "{err}");
}

#[test]
fn short_stimulus_row_errors_before_reaching_any_engine() {
    // regression (ISSUE 4): a short feature row used to panic with an
    // out-of-bounds index deep inside the bit-transpose
    let err = PackedStimulus::from_features(&[vec![1i64, 2, 3], vec![4]], 3, 4).unwrap_err();
    assert!(err.contains("row 1") && err.contains("din = 3"), "{err}");
}

//! Property tests for the bit-sliced forward engine (ISSUE 4 tentpole):
//! `BitSliceEval` must be *bit-exact* against `axsum::forward` and
//! `FlatEval::forward_batch` on fuzzed models and plans of every decoder
//! family, across the 64-pattern chunk edges and the adversarial
//! stimulus corners (all-zero / all-saturated) — plus the end-to-end
//! guarantee that a DSE sweep under the bitslice backend reproduces the
//! flat backend's evaluations exactly.

use axmlp::axsum::{self, BitSliceEval, BitSliceScratch, FlatEval, FlatScratch};
use axmlp::conformance::gen::{self, PlanKind, TopologyRange};
use axmlp::dse::{evaluate_design, DseConfig, EvalBackend, QuantData};
use axmlp::pdk::EgtLibrary;
use axmlp::sim::PackedStimulus;
use axmlp::util::rng::Rng;

#[test]
fn bitslice_logits_match_reference_on_fuzzed_models_all_plan_families() {
    let mut rng = Rng::new(0xB5);
    let mut scratch = Vec::new();
    for case in 0..30 {
        let q = gen::random_quant_mlp(&mut rng, &TopologyRange::default());
        // chunk-edge pattern counts: the packer's boundary handling is
        // the likeliest divergence site
        let total = [63usize, 64, 65, 1, 40, 129][case % 6];
        let xs = gen::mixed_stimulus(&mut rng, &q, total);
        let kind = PlanKind::ALL[case % PlanKind::ALL.len()];
        let plan = gen::plan_of_kind(&mut rng, &q, &xs, kind);

        let flat = FlatEval::new(&q, &plan);
        let mut fs = FlatScratch::new();
        let mut want = Vec::new();
        flat.forward_batch(&xs, &mut want, &mut fs);

        let bs = BitSliceEval::new(&q, &plan);
        let mut bss = BitSliceScratch::new();
        let mut got = Vec::new();
        bs.forward_batch(&xs, &mut got, &mut bss);
        assert_eq!(got, want, "case {case} ({}, {total} patterns)", kind.name());

        // spot-check against the per-sample reference forward too
        let dout = q.dout();
        for (p, x) in xs.iter().enumerate().take(5) {
            let r = axsum::forward(&q, &plan, x, &mut scratch);
            assert_eq!(&got[p * dout..(p + 1) * dout], &r[..], "case {case} pattern {p}");
        }
    }
}

#[test]
fn bitslice_forward_packed_shares_the_simulator_transpose() {
    // the packed entry point consumes the exact PackedStimulus the
    // netlist simulator uses — one transpose, two engines
    let mut rng = Rng::new(0xB6);
    let q = gen::random_quant_mlp(&mut rng, &TopologyRange::default());
    let xs = gen::mixed_stimulus(&mut rng, &q, 65);
    let plan = gen::plan_of_kind(&mut rng, &q, &xs, PlanKind::RandomShifts);
    let packed = PackedStimulus::from_features(&xs, q.din(), q.in_bits).unwrap();

    let bs = BitSliceEval::new(&q, &plan);
    let mut bss = BitSliceScratch::new();
    let mut via_packed = Vec::new();
    bs.forward_packed(&packed, &mut via_packed, &mut bss);
    let mut via_rows = Vec::new();
    bs.forward_batch(&xs, &mut via_rows, &mut bss);
    assert_eq!(via_packed, via_rows);
}

#[test]
fn bitslice_accuracy_matches_flat_on_fuzzed_labels() {
    let mut rng = Rng::new(0xB7);
    for _ in 0..12 {
        let q = gen::random_quant_mlp(&mut rng, &TopologyRange::default());
        let xs = gen::mixed_stimulus(&mut rng, &q, 127);
        let plan = gen::plan_of_kind(&mut rng, &q, &xs, PlanKind::Grid);
        // random labels, deliberately including out-of-range classes
        let ys: Vec<usize> = (0..xs.len()).map(|_| rng.below(q.dout() + 2)).collect();
        let flat = FlatEval::new(&q, &plan);
        let mut fs = FlatScratch::new();
        let bs = BitSliceEval::new(&q, &plan);
        let mut bss = BitSliceScratch::new();
        assert_eq!(
            bs.accuracy_with(&xs, &ys, &mut bss),
            flat.accuracy_with(&xs, &ys, &mut fs)
        );
    }
}

#[test]
fn all_saturated_stimulus_matches_at_chunk_edges() {
    // every input at 2^in_bits - 1 maximizes carry depth in the sliced
    // adders — the worst case for the ripple implementation
    let mut rng = Rng::new(0xB8);
    let q = gen::random_quant_mlp(&mut rng, &TopologyRange::default());
    let a_max = (1i64 << q.in_bits) - 1;
    for total in [63usize, 64, 65] {
        let xs: Vec<Vec<i64>> = (0..total).map(|_| vec![a_max; q.din()]).collect();
        let plan = gen::plan_of_kind(&mut rng, &q, &xs, PlanKind::RandomShifts);
        let flat = FlatEval::new(&q, &plan);
        let mut fs = FlatScratch::new();
        let mut want = Vec::new();
        flat.forward_batch(&xs, &mut want, &mut fs);
        let bs = BitSliceEval::new(&q, &plan);
        let mut bss = BitSliceScratch::new();
        let mut got = Vec::new();
        bs.forward_batch(&xs, &mut got, &mut bss);
        assert_eq!(got, want, "{total} saturated patterns");
    }
}

#[test]
fn dse_point_under_bitslice_backend_is_bit_identical() {
    // evaluate_design dispatches on DseConfig::backend; both backends
    // must produce the same DesignEval for the same point (accuracy from
    // different engines, costs from the same netlist simulation)
    let mut rng = Rng::new(0xB9);
    let q = gen::random_quant_mlp(
        &mut rng,
        &TopologyRange {
            layers: (2, 2),
            din: (4, 6),
            dim: (2, 4),
            ..TopologyRange::default()
        },
    );
    let xs = gen::mixed_stimulus(&mut rng, &q, 160);
    let plan0 = axsum::ShiftPlan::exact(&q);
    let ys: Vec<usize> = xs.iter().map(|x| axsum::predict(&q, &plan0, x)).collect();
    let data = QuantData {
        x_train: &xs[..100],
        y_train: &ys[..100],
        x_test: &xs[100..],
        y_test: &ys[100..],
    };
    let plan = gen::plan_of_kind(&mut rng, &q, &xs, PlanKind::Grid);
    let lib = EgtLibrary::egt_v1();
    let mut cfg = DseConfig {
        max_g_levels: 3,
        power_patterns: 70,
        threads: 2,
        verify_circuit: true,
        max_eval: 0,
        ..DseConfig::default()
    };
    let a = evaluate_design(&q, plan.clone(), 2, vec![0.0; q.n_layers()], &data, &lib, &cfg);
    cfg.backend = EvalBackend::BitSlice;
    let b = evaluate_design(&q, plan, 2, vec![0.0; q.n_layers()], &data, &lib, &cfg);
    assert_eq!(a.acc_train, b.acc_train);
    assert_eq!(a.acc_test, b.acc_test);
    assert_eq!(a.costs, b.costs);
    assert_eq!(a.plan, b.plan);
}

#[test]
fn short_stimulus_row_errors_before_reaching_any_engine() {
    // regression (ISSUE 4): a short feature row used to panic with an
    // out-of-bounds index deep inside the bit-transpose
    let err = PackedStimulus::from_features(&[vec![1i64, 2, 3], vec![4]], 3, 4).unwrap_err();
    assert!(err.contains("row 1") && err.contains("din = 3"), "{err}");
}

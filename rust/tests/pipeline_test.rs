//! End-to-end pipeline integration tests (native backend; the PJRT
//! variants live in runtime_test.rs).

use axmlp::coordinator::{run_dataset, PipelineConfig, SharedContext};
use axmlp::datasets;
use axmlp::dse::DseConfig;
use axmlp::mlp::train::TrainConfig;
use axmlp::retrain::backend_rust::RustBackend;
use axmlp::retrain::RetrainConfig;

fn quick_cfg(thresholds: Vec<f64>) -> PipelineConfig {
    PipelineConfig {
        thresholds,
        dse: DseConfig {
            max_g_levels: 3,
            power_patterns: 48,
            threads: 2,
            verify_circuit: true, // full circuit/software cross-check
            max_eval: 400,
            ..DseConfig::default()
        },
        retrain: RetrainConfig {
            epochs_per_level: 4,
            ..Default::default()
        },
        train: TrainConfig {
            epochs: 60,
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn thresholds_are_monotone_in_area() {
    let ds = datasets::load("v2", 11).expect("dataset");
    let cfg = quick_cfg(vec![0.01, 0.05, 0.10]);
    let ctx = SharedContext::new();
    let out = run_dataset(&ds, &cfg, &ctx, &mut RustBackend).unwrap();
    // looser budgets never cost more area
    for w in out.thresholds.windows(2) {
        assert!(
            w[1].design.costs.area_mm2 <= w[0].design.costs.area_mm2 + 1e-9,
            "area not monotone: {} then {}",
            w[0].design.costs.area_mm2,
            w[1].design.costs.area_mm2
        );
    }
}

#[test]
fn approximate_always_beats_baseline() {
    for key in ["se", "bs"] {
        let ds = datasets::load(key, 5).expect("dataset");
        let cfg = quick_cfg(vec![0.05]);
        let ctx = SharedContext::new();
        let out = run_dataset(&ds, &cfg, &ctx, &mut RustBackend).unwrap();
        let t = &out.thresholds[0];
        assert!(t.area_gain > 1.0, "{key}: area gain {}", t.area_gain);
        assert!(t.power_gain > 1.0, "{key}: power gain {}", t.power_gain);
        // retrain-only sits between baseline and final
        assert!(t.retrain_only_area_gain >= 1.0, "{key}");
        assert!(
            t.area_gain >= t.retrain_only_area_gain - 1e-9,
            "{key}: axsum should add on top of retraining"
        );
    }
}

#[test]
fn accuracy_floor_respected_on_train_split() {
    let ds = datasets::load("ma", 3).expect("dataset");
    let cfg = quick_cfg(vec![0.02]);
    let ctx = SharedContext::new();
    let out = run_dataset(&ds, &cfg, &ctx, &mut RustBackend).unwrap();
    let t = &out.thresholds[0];
    assert!(
        t.design.acc_train >= out.q0_acc_train - 0.02 - 1e-9,
        "{} vs floor {}",
        t.design.acc_train,
        out.q0_acc_train - 0.02
    );
}

#[test]
fn outcome_is_deterministic_in_seed() {
    let ds = datasets::load("v2", 9).expect("dataset");
    let cfg = quick_cfg(vec![0.02]);
    let ctx = SharedContext::new();
    let a = run_dataset(&ds, &cfg, &ctx, &mut RustBackend).unwrap();
    let b = run_dataset(&ds, &cfg, &ctx, &mut RustBackend).unwrap();
    assert_eq!(a.thresholds[0].design.costs.area_mm2, b.thresholds[0].design.costs.area_mm2);
    assert_eq!(a.thresholds[0].design.acc_test, b.thresholds[0].design.acc_test);
    assert_eq!(a.thresholds[0].model.w, b.thresholds[0].model.w);
}

#[test]
fn pareto_cloud_contains_exact_point() {
    let ds = datasets::load("se", 7).expect("dataset");
    let cfg = quick_cfg(vec![0.05]);
    let ctx = SharedContext::new();
    let out = run_dataset(&ds, &cfg, &ctx, &mut RustBackend).unwrap();
    assert!(!out.pareto_cloud.is_empty());
    // at least one untruncated point in the cloud
    assert!(out.pareto_cloud.iter().any(|&(_, _, _, _, trunc)| trunc == 0));
}

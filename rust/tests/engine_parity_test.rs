//! Golden parity tests for the sweep evaluation engine.
//!
//! The packed-stimulus / zero-alloc / plan-dedup engine must be
//! *bit-exact* against the pre-refactor evaluation path. The golden
//! references here are self-contained reimplementations of the seed
//! algorithms (per-chunk input repacking simulation; per-sample
//! `Vec<Vec<i64>>` forward walk), so any behavioral drift in the engine —
//! including at the 64-pattern chunk boundary — fails these tests even if
//! both halves of the new code drift together.

use std::collections::HashMap;

use axmlp::axsum::{
    self, derive_shifts, mean_activations, neuron_value, significance, FlatEval, FlatScratch,
    ShiftPlan,
};
use axmlp::dse::{
    circuit_costs, circuit_costs_packed, enumerate_points, evaluate_design, sweep, DseConfig,
    QuantData,
};
use axmlp::fixed::QuantMlp;
use axmlp::netlist::Netlist;
use axmlp::pdk::{CellKind, EgtLibrary};
use axmlp::sim::{simulate, simulate_packed, PackedStimulus, SimScratch};
use axmlp::synth::{build_mlp, MlpCircuitSpec, NeuronStyle};
use axmlp::util::rng::Rng;

// ---------------------------------------------------------------------------
// Golden reference #1: the seed's word-parallel simulator (inputs repacked
// bit-by-bit per chunk, fresh buffers per call).
// ---------------------------------------------------------------------------

fn reference_simulate(
    nl: &Netlist,
    inputs: &HashMap<String, Vec<u64>>,
    patterns: usize,
    capture_toggles: bool,
) -> (HashMap<String, Vec<u64>>, Vec<u64>) {
    let n = nl.gates.len();
    let mut toggles = if capture_toggles { vec![0u64; n] } else { Vec::new() };
    let mut outputs: HashMap<String, Vec<u64>> = nl
        .outputs
        .iter()
        .map(|b| (b.name.clone(), Vec::with_capacity(patterns)))
        .collect();
    let mut words = vec![0u64; n];
    let mut prev_last = vec![0u64; n];
    let chunks = patterns.div_ceil(64);

    for chunk in 0..chunks {
        let base = chunk * 64;
        let in_chunk = (patterns - base).min(64);
        for bus in &nl.inputs {
            let vals = inputs.get(&bus.name);
            for (biti, &net) in bus.nets.iter().enumerate() {
                let mut w = 0u64;
                for p in 0..in_chunk {
                    let v = vals.and_then(|v| v.get(base + p)).copied().unwrap_or(0);
                    if (v >> biti) & 1 == 1 {
                        w |= 1u64 << p;
                    }
                }
                words[net as usize] = w;
            }
        }
        let mask = if in_chunk == 64 {
            u64::MAX
        } else {
            (1u64 << in_chunk) - 1
        };
        for (i, g) in nl.gates.iter().enumerate() {
            let w = match g.kind {
                CellKind::Input => words[i],
                CellKind::Const0 => 0,
                CellKind::Const1 => u64::MAX,
                CellKind::Buf => words[g.ins[0] as usize],
                CellKind::Inv => !words[g.ins[0] as usize],
                CellKind::And2 => words[g.ins[0] as usize] & words[g.ins[1] as usize],
                CellKind::Or2 => words[g.ins[0] as usize] | words[g.ins[1] as usize],
                CellKind::Nand2 => !(words[g.ins[0] as usize] & words[g.ins[1] as usize]),
                CellKind::Nor2 => !(words[g.ins[0] as usize] | words[g.ins[1] as usize]),
                CellKind::Xor2 => words[g.ins[0] as usize] ^ words[g.ins[1] as usize],
                CellKind::Xnor2 => !(words[g.ins[0] as usize] ^ words[g.ins[1] as usize]),
                CellKind::Mux2 => {
                    let s = words[g.ins[0] as usize];
                    (s & words[g.ins[1] as usize]) | (!s & words[g.ins[2] as usize])
                }
            };
            words[i] = w;
            if capture_toggles {
                let wm = w & mask;
                let within = (wm ^ (wm >> 1)) & (mask >> 1);
                let mut t = within.count_ones() as u64;
                if chunk > 0 && (wm & 1) != prev_last[i] {
                    t += 1;
                }
                toggles[i] += t;
                prev_last[i] = (wm >> (in_chunk - 1)) & 1;
            }
        }
        for bus in &nl.outputs {
            let dst = outputs.get_mut(&bus.name).unwrap();
            for p in 0..in_chunk {
                let mut v = 0u64;
                for (biti, &net) in bus.nets.iter().enumerate() {
                    if (words[net as usize] >> p) & 1 == 1 {
                        v |= 1u64 << biti;
                    }
                }
                dst.push(v);
            }
        }
    }
    (outputs, toggles)
}

// ---------------------------------------------------------------------------
// Golden reference #2: the seed's per-sample accuracy walk (fresh Vec per
// layer per sample, same neuron_value inner loop).
// ---------------------------------------------------------------------------

fn reference_forward(q: &QuantMlp, plan: &ShiftPlan, x: &[i64]) -> Vec<i64> {
    let mut acts: Vec<i64> = x.to_vec();
    let n_layers = q.n_layers();
    for l in 0..n_layers {
        let mut next: Vec<i64> = Vec::with_capacity(q.w[l].len());
        for (j, row) in q.w[l].iter().enumerate() {
            let v = neuron_value(&acts, row, q.b[l][j], &plan.shifts[l][j]);
            next.push(if l + 1 < n_layers { v.max(0) } else { v });
        }
        acts = next;
    }
    acts
}

fn reference_accuracy(q: &QuantMlp, plan: &ShiftPlan, xs: &[Vec<i64>], ys: &[usize]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let ok = xs
        .iter()
        .zip(ys)
        .filter(|(x, &y)| {
            axmlp::util::stats::argmax_i64(&reference_forward(q, plan, x)) == y
        })
        .count();
    ok as f64 / xs.len() as f64
}

// ---------------------------------------------------------------------------
// Shared fixtures
// ---------------------------------------------------------------------------

fn rand_q(rng: &mut Rng, din: usize, hidden: usize, dout: usize, in_bits: usize) -> QuantMlp {
    QuantMlp {
        w: vec![
            (0..hidden)
                .map(|_| (0..din).map(|_| rng.range_i64(-90, 90)).collect())
                .collect(),
            (0..dout)
                .map(|_| (0..hidden).map(|_| rng.range_i64(-90, 90)).collect())
                .collect(),
        ],
        b: vec![
            (0..hidden).map(|_| rng.range_i64(-40, 40)).collect(),
            (0..dout).map(|_| rng.range_i64(-40, 40)).collect(),
        ],
        in_bits,
        w_scales: vec![1.0, 1.0],
    }
}

fn rand_plan(rng: &mut Rng, q: &QuantMlp) -> ShiftPlan {
    let mut plan = ShiftPlan::exact(q);
    for layer in plan.shifts.iter_mut() {
        for row in layer.iter_mut() {
            for s in row.iter_mut() {
                *s = rng.below(5) as u32;
            }
        }
    }
    plan
}

fn rand_inputs(rng: &mut Rng, din: usize, n: usize, hi: i64) -> Vec<Vec<i64>> {
    (0..n)
        .map(|_| (0..din).map(|_| rng.range_i64(0, hi)).collect())
        .collect()
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[test]
fn packed_simulation_bit_matches_seed_simulator_across_chunk_boundaries() {
    let mut rng = Rng::new(0xA1);
    let q = rand_q(&mut rng, 5, 3, 3, 4);
    let plan = rand_plan(&mut rng, &q);
    let spec = MlpCircuitSpec {
        name: "parity".into(),
        weights: q.w.clone(),
        biases: q.b.clone(),
        shifts: plan.shifts.clone(),
        in_bits: 4,
        style: NeuronStyle::AxSum,
    };
    let nl = build_mlp(&spec);
    // 63/64/65 straddle the first word boundary; 130 crosses two
    for pats in [1usize, 63, 64, 65, 128, 130] {
        let xs = rand_inputs(&mut rng, 5, pats, 15);
        let mut inputs: HashMap<String, Vec<u64>> = HashMap::new();
        for i in 0..5 {
            inputs.insert(format!("x{i}"), xs.iter().map(|x| x[i] as u64).collect());
        }
        let (ref_out, ref_toggles) = reference_simulate(&nl, &inputs, pats, true);
        // packed core against a shared scratch
        let stim = PackedStimulus::for_netlist(&nl, &inputs, pats);
        let mut scratch = SimScratch::new();
        simulate_packed(&nl, &stim, true, &mut scratch);
        assert_eq!(
            scratch.output(&nl, "class").unwrap(),
            &ref_out["class"][..],
            "{pats} patterns: outputs"
        );
        assert_eq!(scratch.toggles, ref_toggles, "{pats} patterns: toggles");
        // legacy wrapper stays bit-exact too
        let r = simulate(&nl, &inputs, pats, true);
        assert_eq!(r.outputs["class"], ref_out["class"]);
        assert_eq!(r.toggles, ref_toggles);
        assert_eq!(r.patterns, pats);
    }
}

#[test]
fn flat_accuracy_bit_matches_seed_walk() {
    let mut rng = Rng::new(0xB2);
    for _ in 0..8 {
        let q = rand_q(&mut rng, 6, 4, 3, 4);
        let plan = rand_plan(&mut rng, &q);
        let xs = rand_inputs(&mut rng, 6, 150, 15);
        let ys: Vec<usize> = (0..150).map(|_| rng.below(3)).collect();
        assert_eq!(
            axsum::accuracy(&q, &plan, &xs, &ys),
            reference_accuracy(&q, &plan, &xs, &ys)
        );
        let flat = FlatEval::new(&q, &plan);
        let mut fs = FlatScratch::new();
        for x in &xs {
            assert_eq!(flat.forward_into(x, &mut fs), &reference_forward(&q, &plan, x)[..]);
        }
    }
}

#[test]
fn mean_activations_unchanged_by_scratch_reuse() {
    // the significance pipeline input must stay bit-identical (f64 sums
    // accumulate in the same order as the seed implementation)
    let mut rng = Rng::new(0xC3);
    let q = rand_q(&mut rng, 5, 4, 3, 4);
    let xs = rand_inputs(&mut rng, 5, 120, 15);
    let plan = ShiftPlan::exact(&q);
    let means = mean_activations(&q, &xs);
    // reference: accumulate from reference_forward's hidden layer
    let mut sums = vec![vec![0.0f64; q.din()], vec![0.0f64; q.hidden()]];
    for x in &xs {
        for (i, &v) in x.iter().enumerate() {
            sums[0][i] += v as f64;
        }
        for (j, row) in q.w[0].iter().enumerate() {
            let v = neuron_value(x, row, q.b[0][j], &plan.shifts[0][j]).max(0);
            sums[1][j] += v as f64;
        }
    }
    let n = xs.len() as f64;
    for layer in sums.iter_mut() {
        for v in layer.iter_mut() {
            *v /= n;
        }
    }
    assert_eq!(means, sums);
}

#[test]
fn circuit_costs_wrapper_and_packed_core_agree_at_chunk_boundary() {
    let mut rng = Rng::new(0xD4);
    let q = rand_q(&mut rng, 4, 3, 3, 4);
    let plan = rand_plan(&mut rng, &q);
    let lib = EgtLibrary::egt_v1();
    for pats in [65usize, 128] {
        let xs = rand_inputs(&mut rng, 4, pats, 15);
        let (costs, classes) = circuit_costs(&q, &plan, NeuronStyle::AxSum, &xs, &lib);
        let packed = PackedStimulus::from_features(&xs, q.din(), q.in_bits).unwrap();
        let mut scratch = SimScratch::new();
        let costs2 = circuit_costs_packed(&q, &plan, NeuronStyle::AxSum, &packed, &lib, &mut scratch);
        assert_eq!(costs, costs2);
        assert_eq!(classes, scratch.outputs[0]);
        // and the simulated classes match the software oracle
        for (x, &cls) in xs.iter().zip(&classes) {
            assert_eq!(axsum::predict(&q, &plan, x), cls as usize);
        }
    }
}

#[test]
fn sweep_bit_matches_per_point_evaluation() {
    // the dedup + fan-out engine must return exactly what independent
    // per-point evaluation returns, point for point, in grid order
    let mut rng = Rng::new(0xE5);
    let q = rand_q(&mut rng, 4, 3, 3, 4);
    let xs = rand_inputs(&mut rng, 4, 180, 15);
    let plan0 = ShiftPlan::exact(&q);
    let ys: Vec<usize> = xs.iter().map(|x| axsum::predict(&q, &plan0, x)).collect();
    let data = QuantData {
        x_train: &xs[..120],
        y_train: &ys[..120],
        x_test: &xs[120..],
        y_test: &ys[120..],
    };
    let means = mean_activations(&q, data.x_train);
    let sig = significance(&q, &means);
    let cfg = DseConfig {
        max_g_levels: 3,
        power_patterns: 70, // crosses the 64-pattern chunk boundary
        threads: 4,
        verify_circuit: true,
        max_eval: 0,
        ..DseConfig::default()
    };
    let designs = sweep(&q, &sig, &data, &EgtLibrary::egt_v1(), &cfg).unwrap();
    let points = enumerate_points(&q, &sig, &cfg);
    assert_eq!(designs.len(), points.len());
    for (d, (k, g)) in designs.iter().zip(&points) {
        let plan = derive_shifts(&q, &sig, g, *k);
        let want = evaluate_design(
            &q,
            plan,
            *k,
            g.clone(),
            &data,
            &EgtLibrary::egt_v1(),
            &cfg,
        )
        .unwrap();
        assert_eq!(d.k, want.k);
        assert_eq!(d.g, want.g);
        assert_eq!(d.plan, want.plan);
        assert_eq!(d.acc_train, want.acc_train, "k={k} g={g:?}");
        assert_eq!(d.acc_test, want.acc_test, "k={k} g={g:?}");
        assert_eq!(d.costs, want.costs, "k={k} g={g:?}");
    }
}

#[test]
fn sweep_dedup_fan_out_covers_aliasing_points() {
    // with 1-bit inputs and ±1 weights every layer-1 product is
    // n_i = 2 bits wide, so for any G that only truncates layer 1
    // (layer-2 threshold disabled) k=2 and k=3 derive the *same* plan:
    // the sweep must collapse such grid points internally yet still
    // report every point with its own (k, g) labels and identical
    // results
    let q = QuantMlp {
        w: vec![
            vec![vec![1, 1, 0, 0], vec![0, 1, 1, 0], vec![1, 0, 0, 1]],
            vec![vec![1, -1, 0], vec![0, 1, 1]],
        ],
        b: vec![vec![1, 0, -1], vec![0, 1]],
        in_bits: 1,
        w_scales: vec![1.0, 1.0],
    };
    // all 16 4-bit vectors, cycled: every feature mean is exactly 0.5,
    // so every nonzero product has a finite significance candidate
    let xs: Vec<Vec<i64>> = (0..96)
        .map(|p| (0..4).map(|i| ((p % 16) >> i) as i64 & 1).collect())
        .collect();
    let plan0 = ShiftPlan::exact(&q);
    let ys: Vec<usize> = xs.iter().map(|x| axsum::predict(&q, &plan0, x)).collect();
    let data = QuantData {
        x_train: &xs[..60],
        y_train: &ys[..60],
        x_test: &xs[60..],
        y_test: &ys[60..],
    };
    let means = mean_activations(&q, data.x_train);
    let sig = significance(&q, &means);
    let cfg = DseConfig {
        max_g_levels: 3,
        power_patterns: 30,
        threads: 2,
        verify_circuit: true,
        max_eval: 0,
        ..DseConfig::default()
    };
    let designs = sweep(&q, &sig, &data, &EgtLibrary::egt_v1(), &cfg).unwrap();
    let points = enumerate_points(&q, &sig, &cfg);
    assert_eq!(designs.len(), points.len());
    // find an aliasing (k=2, g) / (k=3, g) pair and check label + result
    let mut alias_checked = false;
    for d2 in designs.iter().filter(|d| d.k == 2) {
        if let Some(d3) = designs.iter().find(|d| d.k == 3 && d.g == d2.g) {
            let p2 = derive_shifts(&q, &sig, &d2.g, 2);
            let p3 = derive_shifts(&q, &sig, &d3.g, 3);
            if p2 == p3 {
                assert_eq!(d2.plan, d3.plan);
                assert_eq!(d2.acc_train, d3.acc_train);
                assert_eq!(d2.costs, d3.costs);
                assert_eq!(d2.k, 2);
                assert_eq!(d3.k, 3);
                alias_checked = true;
            }
        }
    }
    assert!(alias_checked, "fixture must produce at least one plan alias");
}

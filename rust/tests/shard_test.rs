//! Sharded-sweep engine: parity with the monolithic sweep, checkpoint /
//! resume semantics (kill-mid-sweep, no re-evaluation of finished
//! shards), the corruption error paths (contextful errors, never a
//! panic, never silently-wrong results), and the multi-process claiming
//! layer (two-claimer races, kill-at-every-write-site work stealing,
//! orphan tmp reaping).

use axmlp::axsum::{self, mean_activations, significance, ShiftPlan, Significance};
use axmlp::dse::shard::{
    first_divergence, sweep_sharded, ClaimConfig, KillSite, ShardConfig,
};
use axmlp::dse::{self, DesignEval, DseConfig, EvalBackend, QuantData};
use axmlp::fixed::QuantMlp;
use axmlp::pdk::EgtLibrary;
use axmlp::util::rng::Rng;

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

fn scratch_dir(tag: &str) -> PathBuf {
    static N: AtomicUsize = AtomicUsize::new(0);
    let dir = std::env::temp_dir().join(format!(
        "axmlp_shard_test_{}_{}_{}",
        std::process::id(),
        tag,
        N.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Self-labeled toy model (exact forward generates the labels, so the
/// exact design point scores 1.0 and truncation trades accuracy).
fn toy(seed: u64) -> (QuantMlp, Vec<Vec<i64>>, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let q = QuantMlp {
        w: vec![
            (0..3)
                .map(|_| (0..4).map(|_| rng.range_i64(-90, 90)).collect())
                .collect(),
            (0..3)
                .map(|_| (0..3).map(|_| rng.range_i64(-90, 90)).collect())
                .collect(),
        ],
        b: vec![
            (0..3).map(|_| rng.range_i64(-40, 40)).collect(),
            (0..3).map(|_| rng.range_i64(-40, 40)).collect(),
        ],
        in_bits: 4,
        w_scales: vec![1.0, 1.0],
    };
    let xs: Vec<Vec<i64>> = (0..180)
        .map(|_| (0..4).map(|_| rng.range_i64(0, 15)).collect())
        .collect();
    let plan = ShiftPlan::exact(&q);
    let ys: Vec<usize> = xs.iter().map(|x| axsum::predict(&q, &plan, x)).collect();
    (q, xs, ys)
}

fn sig_of(q: &QuantMlp, xs: &[Vec<i64>]) -> Significance {
    significance(q, &mean_activations(q, xs))
}

fn cfg_small(backend: EvalBackend) -> DseConfig {
    DseConfig {
        max_g_levels: 3,
        power_patterns: 24,
        threads: 4,
        verify_circuit: false,
        max_eval: 0,
        backend,
    }
}

fn assert_bit_identical(a: &[DesignEval], b: &[DesignEval]) {
    if let Some((p, field, detail)) = first_divergence(a, b) {
        panic!("eval lists diverge at {p} ({field}): {detail}");
    }
}

#[test]
fn sharded_sweep_matches_monolithic_under_both_backends() {
    let (q, xs, ys) = toy(41);
    let data = QuantData {
        x_train: &xs[..130],
        y_train: &ys[..130],
        x_test: &xs[130..],
        y_test: &ys[130..],
    };
    let sig = sig_of(&q, data.x_train);
    let lib = EgtLibrary::egt_v1();
    for backend in [EvalBackend::Flat, EvalBackend::BitSlice] {
        let cfg = cfg_small(backend);
        let mono = dse::sweep(&q, &sig, &data, &lib, &cfg).unwrap();
        for shards in [2usize, 5] {
            let scfg = ShardConfig {
                shards,
                ..ShardConfig::default()
            };
            let rep = sweep_sharded(&q, &sig, &data, &lib, &cfg, &scfg).unwrap();
            assert_bit_identical(&rep.evals, &mono);
        }
    }
}

#[test]
fn kill_mid_sweep_then_resume_is_bit_identical_and_skips_finished_shards() {
    let (q, xs, ys) = toy(42);
    let data = QuantData {
        x_train: &xs[..130],
        y_train: &ys[..130],
        x_test: &xs[130..],
        y_test: &ys[130..],
    };
    let sig = sig_of(&q, data.x_train);
    let lib = EgtLibrary::egt_v1();
    let cfg = cfg_small(EvalBackend::Flat);
    let mono = dse::sweep(&q, &sig, &data, &lib, &cfg).unwrap();

    let dir = scratch_dir("kill");
    let shards = 4;
    let killed = ShardConfig {
        shards,
        checkpoint_dir: Some(dir.clone()),
        resume: false,
        stop_after: Some(2), // die after 2 of 4 shards
        claim: None,
    };
    let err = sweep_sharded(&q, &sig, &data, &lib, &cfg, &killed)
        .err()
        .expect("interrupted run must not return a full result");
    assert!(err.to_string().contains("interrupted"), "{err}");
    // exactly the finished shards are checkpointed, atomically (no .tmp)
    for s in 0..shards {
        let p = dir.join(format!("shard_{s:04}.json"));
        assert_eq!(p.exists(), s < 2, "shard {s}");
        assert!(!dir.join(format!("shard_{s:04}.json.tmp")).exists());
    }

    let resumed_cfg = ShardConfig {
        shards,
        checkpoint_dir: Some(dir.clone()),
        resume: true,
        stop_after: None,
        claim: None,
    };
    let resumed = sweep_sharded(&q, &sig, &data, &lib, &cfg, &resumed_cfg).unwrap();
    assert_eq!(resumed.shards_resumed, 2, "finished shards are not re-evaluated");
    assert_eq!(resumed.shards_evaluated, 2);
    assert_bit_identical(&resumed.evals, &mono);

    // a second resume is a pure load (everything checkpointed now)
    let again = sweep_sharded(&q, &sig, &data, &lib, &cfg, &resumed_cfg).unwrap();
    assert_eq!(again.shards_resumed, shards);
    assert_eq!(again.shards_evaluated, 0);
    assert_bit_identical(&again.evals, &mono);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_loads_checkpoints_verbatim_instead_of_recomputing() {
    // tamper one recorded accuracy on disk: the resumed sweep must carry
    // the sentinel value through, proving finished shards are loaded,
    // not re-evaluated (the conformance sweep canary then proves such a
    // corruption is *caught* when differenced against the monolithic run)
    let (q, xs, ys) = toy(43);
    let data = QuantData {
        x_train: &xs[..130],
        y_train: &ys[..130],
        x_test: &xs[130..],
        y_test: &ys[130..],
    };
    let sig = sig_of(&q, data.x_train);
    let lib = EgtLibrary::egt_v1();
    let cfg = cfg_small(EvalBackend::Flat);
    let dir = scratch_dir("verbatim");
    let scfg = ShardConfig {
        shards: 3,
        checkpoint_dir: Some(dir.clone()),
        resume: false,
        stop_after: None,
        claim: None,
    };
    sweep_sharded(&q, &sig, &data, &lib, &cfg, &scfg).unwrap();

    let path = dir.join("shard_0000.json");
    let sentinel = "0.123456789";
    let sentinel_v: f64 = sentinel.parse().unwrap();
    let raw = std::fs::read_to_string(&path).unwrap();
    let needle = "\"acc_train\": ";
    let at = raw.find(needle).expect("shard JSON records acc_train");
    let end = raw[at + needle.len()..].find(',').unwrap() + at + needle.len();
    let tampered = format!("{}{}{}", &raw[..at + needle.len()], sentinel, &raw[end..]);
    std::fs::write(&path, tampered).unwrap();

    let rcfg = ShardConfig {
        resume: true,
        ..scfg
    };
    let resumed = sweep_sharded(&q, &sig, &data, &lib, &cfg, &rcfg).unwrap();
    assert_eq!(resumed.shards_resumed, 3);
    let hits = resumed
        .evals
        .iter()
        .filter(|e| e.acc_train.to_bits() == sentinel_v.to_bits())
        .count();
    assert!(hits > 0, "sentinel accuracy must surface in the resumed evals");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_manifest_is_a_contextful_error() {
    let (q, xs, ys) = toy(44);
    let data = QuantData {
        x_train: &xs[..130],
        y_train: &ys[..130],
        x_test: &xs[130..],
        y_test: &ys[130..],
    };
    let sig = sig_of(&q, data.x_train);
    let lib = EgtLibrary::egt_v1();
    let cfg = cfg_small(EvalBackend::Flat);
    let dir = scratch_dir("manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{\"version\": 1, truncated").unwrap();
    let scfg = ShardConfig {
        shards: 2,
        checkpoint_dir: Some(dir.clone()),
        resume: true,
        stop_after: None,
        claim: None,
    };
    let err = sweep_sharded(&q, &sig, &data, &lib, &cfg, &scfg)
        .err()
        .expect("corrupted manifest must fail the resume");
    let msg = err.to_string();
    assert!(msg.contains("manifest"), "{msg}");
    assert!(msg.contains("manifest.json"), "names the file: {msg}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_shard_checkpoint_is_a_contextful_error() {
    // atomic writes mean the engine itself can never produce a truncated
    // shard file; if external corruption does, resume must refuse with an
    // error naming the file — not panic, not silently re-evaluate
    let (q, xs, ys) = toy(45);
    let data = QuantData {
        x_train: &xs[..130],
        y_train: &ys[..130],
        x_test: &xs[130..],
        y_test: &ys[130..],
    };
    let sig = sig_of(&q, data.x_train);
    let lib = EgtLibrary::egt_v1();
    let cfg = cfg_small(EvalBackend::Flat);
    let dir = scratch_dir("truncated");
    let scfg = ShardConfig {
        shards: 3,
        checkpoint_dir: Some(dir.clone()),
        resume: false,
        stop_after: None,
        claim: None,
    };
    sweep_sharded(&q, &sig, &data, &lib, &cfg, &scfg).unwrap();
    let path = dir.join("shard_0001.json");
    let raw = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, &raw[..raw.len() / 2]).unwrap();

    let rcfg = ShardConfig {
        resume: true,
        ..scfg
    };
    let err = sweep_sharded(&q, &sig, &data, &lib, &cfg, &rcfg)
        .err()
        .expect("truncated shard must fail the resume");
    let msg = err.to_string();
    assert!(msg.contains("shard_0001.json"), "names the file: {msg}");
    assert!(msg.contains("delete the file"), "remediation hint: {msg}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn manifestless_resume_refuses_to_delete_orphan_shards() {
    // a partial restore that lost manifest.json but kept shard files
    // must not be silently wiped by a resume — the engine refuses and
    // leaves the checkpoints untouched
    let (q, xs, ys) = toy(47);
    let data = QuantData {
        x_train: &xs[..130],
        y_train: &ys[..130],
        x_test: &xs[130..],
        y_test: &ys[130..],
    };
    let sig = sig_of(&q, data.x_train);
    let lib = EgtLibrary::egt_v1();
    let cfg = cfg_small(EvalBackend::Flat);
    let dir = scratch_dir("orphans");
    let scfg = ShardConfig {
        shards: 2,
        checkpoint_dir: Some(dir.clone()),
        resume: false,
        stop_after: None,
        claim: None,
    };
    sweep_sharded(&q, &sig, &data, &lib, &cfg, &scfg).unwrap();
    std::fs::remove_file(dir.join("manifest.json")).unwrap();

    let rcfg = ShardConfig {
        resume: true,
        ..scfg
    };
    let err = sweep_sharded(&q, &sig, &data, &lib, &cfg, &rcfg)
        .err()
        .expect("manifest-less resume over surviving shards must refuse");
    assert!(err.to_string().contains("no manifest.json"), "{err}");
    // the orphaned checkpoints survived the refusal
    assert!(dir.join("shard_0000.json").exists());
    assert!(dir.join("shard_0001.json").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_refuses_a_checkpoint_from_a_different_space() {
    // same directory, different backend → different fingerprint: the
    // engine must refuse to mix results rather than resume wrong ones
    let (q, xs, ys) = toy(46);
    let data = QuantData {
        x_train: &xs[..130],
        y_train: &ys[..130],
        x_test: &xs[130..],
        y_test: &ys[130..],
    };
    let sig = sig_of(&q, data.x_train);
    let lib = EgtLibrary::egt_v1();
    let dir = scratch_dir("space");
    let scfg = ShardConfig {
        shards: 2,
        checkpoint_dir: Some(dir.clone()),
        resume: false,
        stop_after: None,
        claim: None,
    };
    sweep_sharded(&q, &sig, &data, &lib, &cfg_small(EvalBackend::Flat), &scfg).unwrap();
    let rcfg = ShardConfig {
        resume: true,
        ..scfg
    };
    let err = sweep_sharded(&q, &sig, &data, &lib, &cfg_small(EvalBackend::BitSlice), &rcfg)
        .err()
        .expect("fingerprint mismatch must fail the resume");
    assert!(err.to_string().contains("fingerprint"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sharded_strategy_pipeline_matches_grid_strategy() {
    use axmlp::coordinator::{
        run_dataset, DseStrategy, PipelineConfig, ShardStrategy, SharedContext,
    };
    use axmlp::datasets;
    use axmlp::mlp::train::TrainConfig;
    use axmlp::retrain::backend_rust::RustBackend;
    use axmlp::retrain::RetrainConfig;

    let ds = datasets::load("ma", 7).expect("dataset");
    let base = PipelineConfig {
        thresholds: vec![0.05],
        dse: DseConfig {
            max_g_levels: 3,
            power_patterns: 32,
            threads: 4,
            verify_circuit: false,
            max_eval: 0,
            ..DseConfig::default()
        },
        retrain: RetrainConfig {
            epochs_per_level: 3,
            ..Default::default()
        },
        train: TrainConfig {
            epochs: 40,
            ..Default::default()
        },
        ..Default::default()
    };
    let ctx = SharedContext::new();
    let grid_out = {
        let mut be = RustBackend;
        run_dataset(&ds, &base, &ctx, &mut be).unwrap()
    };
    let dir = scratch_dir("pipeline");
    let sharded_out = {
        let mut cfg = base.clone();
        cfg.strategy = DseStrategy::Sharded(ShardStrategy {
            shards: 3,
            checkpoint_dir: Some(dir.to_string_lossy().into_owned()),
            resume: false,
            ..Default::default()
        });
        let mut be = RustBackend;
        run_dataset(&ds, &cfg, &ctx, &mut be).unwrap()
    };
    // the sharded strategy must pick the exact same design
    let (g, s) = (&grid_out.thresholds[0], &sharded_out.thresholds[0]);
    assert_eq!(g.design.plan, s.design.plan);
    assert_eq!(g.design.acc_train.to_bits(), s.design.acc_train.to_bits());
    assert_eq!(g.design.costs, s.design.costs);
    assert_eq!(grid_out.pareto_cloud, sharded_out.pareto_cloud);
    // per-dataset/threshold checkpoints landed under the root
    assert!(dir.join("ma_t500").join("manifest.json").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn two_claimers_race_on_a_single_shard() {
    // the tightest contention case: one shard, two claimers. Exactly one
    // wins the create-exclusive claim; the loser waits and loads the
    // winner's checkpoint. Both merged fronts must equal the monolithic
    // sweep bit-for-bit.
    let (q, xs, ys) = toy(48);
    let data = QuantData {
        x_train: &xs[..130],
        y_train: &ys[..130],
        x_test: &xs[130..],
        y_test: &ys[130..],
    };
    let sig = sig_of(&q, data.x_train);
    let lib = EgtLibrary::egt_v1();
    let cfg = cfg_small(EvalBackend::Flat);
    let mono = dse::sweep(&q, &sig, &data, &lib, &cfg).unwrap();

    let dir = scratch_dir("race1");
    let results: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..2)
            .map(|i| {
                let ccfg = ShardConfig {
                    shards: 1,
                    checkpoint_dir: Some(dir.clone()),
                    resume: false,
                    stop_after: None,
                    claim: Some(ClaimConfig {
                        // same-process claimers must not share the pid
                        // default — every live claimer needs its own id
                        owner_id: format!("racer-{i}"),
                        lease_ms: 400,
                        kill_at: None,
                    }),
                };
                let (q, sig, data, lib, cfg) = (&q, &sig, &data, &lib, &cfg);
                s.spawn(move || sweep_sharded(q, sig, data, lib, cfg, &ccfg))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut evaluated = 0;
    let mut resumed = 0;
    for r in results {
        let rep = r.expect("both claimers must converge on the full front");
        evaluated += rep.shards_evaluated;
        resumed += rep.shards_resumed;
        assert_bit_identical(&rep.evals, &mono);
    }
    // someone evaluated the shard; double evaluation under a lost race
    // is benign (identical bytes) but waiting-and-loading is the norm
    assert!(evaluated >= 1, "the single shard was never evaluated");
    assert!(evaluated + resumed >= 2, "each claimer accounts for the shard");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn killed_claimer_at_every_write_site_is_stolen_and_bit_identical() {
    // property: wherever a claimer dies — before the manifest, holding a
    // fresh claim, or after evaluating but before checkpointing — a
    // later claimer recovers the sweep and reproduces the monolithic
    // front bit-for-bit. `kill_at` leaves the files exactly as `kill -9`
    // would (the claim file survives, unrenewed).
    let (q, xs, ys) = toy(49);
    let data = QuantData {
        x_train: &xs[..130],
        y_train: &ys[..130],
        x_test: &xs[130..],
        y_test: &ys[130..],
    };
    let sig = sig_of(&q, data.x_train);
    let lib = EgtLibrary::egt_v1();
    let cfg = cfg_small(EvalBackend::Flat);
    let mono = dse::sweep(&q, &sig, &data, &lib, &cfg).unwrap();

    for site in [KillSite::PreManifest, KillSite::PostClaim, KillSite::MidShard] {
        let dir = scratch_dir("killsite");
        let victim = ShardConfig {
            shards: 3,
            checkpoint_dir: Some(dir.clone()),
            resume: false,
            stop_after: None,
            claim: Some(ClaimConfig {
                owner_id: "prop-victim".to_string(),
                lease_ms: 1000,
                kill_at: Some(site),
            }),
        };
        let err = sweep_sharded(&q, &sig, &data, &lib, &cfg, &victim)
            .err()
            .unwrap_or_else(|| panic!("{site:?}: killed claimer must not return a result"));
        assert!(err.to_string().contains("interrupted"), "{site:?}: {err}");

        // the recovering claimer judges the victim's claim by its own
        // (short) lease, so the stale claim expires quickly
        let rescuer = ShardConfig {
            shards: 3,
            checkpoint_dir: Some(dir.clone()),
            resume: false,
            stop_after: None,
            claim: Some(ClaimConfig {
                owner_id: "prop-rescuer".to_string(),
                lease_ms: 50,
                kill_at: None,
            }),
        };
        let rep = sweep_sharded(&q, &sig, &data, &lib, &cfg, &rescuer)
            .unwrap_or_else(|e| panic!("{site:?}: rescuer failed: {e}"));
        assert_bit_identical(&rep.evals, &mono);
        if site != KillSite::PreManifest {
            // PostClaim and MidShard leave a stale claim behind — the
            // rescuer must have stolen it, not just claimed fresh shards
            assert!(
                rep.shards_stolen >= 1,
                "{site:?}: expected a steal, got {} stolen / {} evaluated",
                rep.shards_stolen,
                rep.shards_evaluated
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn orphan_tmp_files_are_reaped_and_never_loaded_as_checkpoints() {
    // a writer killed mid-write leaves torn `*.tmp` files behind; reopen
    // must reap them and must never pattern-match them as checkpoints
    let (q, xs, ys) = toy(50);
    let data = QuantData {
        x_train: &xs[..130],
        y_train: &ys[..130],
        x_test: &xs[130..],
        y_test: &ys[130..],
    };
    let sig = sig_of(&q, data.x_train);
    let lib = EgtLibrary::egt_v1();
    let cfg = cfg_small(EvalBackend::Flat);
    let mono = dse::sweep(&q, &sig, &data, &lib, &cfg).unwrap();

    let dir = scratch_dir("tmp_reap");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("shard_0000.json.tmp"), "{\"torn").unwrap();
    std::fs::write(dir.join("manifest.json.12345.tmp"), "{\"torn").unwrap();
    let scfg = ShardConfig {
        shards: 2,
        checkpoint_dir: Some(dir.clone()),
        resume: false,
        stop_after: None,
        claim: None,
    };
    let rep = sweep_sharded(&q, &sig, &data, &lib, &cfg, &scfg).unwrap();
    assert_bit_identical(&rep.evals, &mono);
    assert_eq!(rep.shards_resumed, 0, "a torn tmp is never a checkpoint");
    assert!(!dir.join("shard_0000.json.tmp").exists(), "orphan tmp reaped");
    assert!(!dir.join("manifest.json.12345.tmp").exists(), "orphan tmp reaped");

    // resume over real checkpoints with a fresh torn tmp alongside: the
    // tmp is reaped, the real checkpoints still load verbatim
    std::fs::write(dir.join("shard_0001.json.tmp"), "{\"torn").unwrap();
    let rcfg = ShardConfig {
        resume: true,
        ..scfg
    };
    let rep2 = sweep_sharded(&q, &sig, &data, &lib, &cfg, &rcfg).unwrap();
    assert_eq!(rep2.shards_resumed, 2);
    assert_bit_identical(&rep2.evals, &mono);
    assert!(!dir.join("shard_0001.json.tmp").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

//! Cross-module property tests: the synthesized circuits, the bit-exact
//! software models, and the bound/width bookkeeping must agree on random
//! instances (our substitute for proptest).

use std::collections::HashMap;

use axmlp::axsum::{self, derive_shifts, mean_activations, significance, ShiftPlan};
use axmlp::fixed::QuantMlp;
use axmlp::sim::simulate;
use axmlp::synth::{build_mlp, MlpCircuitSpec, NeuronStyle};
use axmlp::util::prop::{check, check_eq, forall_seeded};
use axmlp::util::rng::Rng;

fn rand_q(rng: &mut Rng) -> QuantMlp {
    let din = 2 + rng.below(6);
    let hidden = 2 + rng.below(4);
    let dout = 2 + rng.below(4);
    QuantMlp {
        w: vec![
            (0..hidden)
                .map(|_| (0..din).map(|_| rng.range_i64(-127, 127)).collect())
                .collect(),
            (0..dout)
                .map(|_| (0..hidden).map(|_| rng.range_i64(-127, 127)).collect())
                .collect(),
        ],
        b: vec![
            (0..hidden).map(|_| rng.range_i64(-60, 60)).collect(),
            (0..dout).map(|_| rng.range_i64(-60, 60)).collect(),
        ],
        in_bits: 4,
        w_scales: vec![1.0, 1.0],
    }
}

fn rand_plan(rng: &mut Rng, q: &QuantMlp) -> ShiftPlan {
    let mut plan = ShiftPlan::exact(q);
    for layer in plan.shifts.iter_mut() {
        for row in layer.iter_mut() {
            for s in row.iter_mut() {
                *s = rng.below(7) as u32;
            }
        }
    }
    plan
}

#[test]
fn circuit_equals_software_model_on_random_mlps() {
    forall_seeded(0xC1, 25, |rng| {
        let q = rand_q(rng);
        let plan = rand_plan(rng, &q);
        let spec = MlpCircuitSpec {
            name: "prop".into(),
            weights: q.w.clone(),
            biases: q.b.clone(),
            shifts: plan.shifts.clone(),
            in_bits: 4,
            style: NeuronStyle::AxSum,
        };
        let nl = build_mlp(&spec);
        let pats = 40;
        let xs: Vec<Vec<i64>> = (0..pats)
            .map(|_| (0..q.din()).map(|_| rng.range_i64(0, 15)).collect())
            .collect();
        let mut inputs: HashMap<String, Vec<u64>> = HashMap::new();
        for i in 0..q.din() {
            inputs.insert(format!("x{i}"), xs.iter().map(|x| x[i] as u64).collect());
        }
        let sim = simulate(&nl, &inputs, pats, false);
        for (x, &cls) in xs.iter().zip(&sim.outputs["class"]) {
            check_eq(
                axsum::predict(&q, &plan, x),
                cls as usize,
                "circuit vs software class",
            )?;
        }
        Ok(())
    });
}

#[test]
fn truncation_monotone_in_k_single_sign() {
    // For an all-positive-coefficient neuron, keeping more MSBs can only
    // move the truncated sum toward the exact one: S'_1 <= S'_2 <= S'_3
    // <= S_exact. (End-to-end MLP error is NOT monotone in k — the Sp/Sn
    // trees can cancel — so the guarantee is stated per single-sign sum.)
    forall_seeded(0xC2, 60, |rng| {
        let n = 1 + rng.below(8);
        let w: Vec<i64> = (0..n).map(|_| rng.range_i64(1, 127)).collect();
        let a: Vec<i64> = (0..n).map(|_| rng.range_i64(0, 15)).collect();
        let bias = rng.range_i64(0, 40);
        let exact = axsum::neuron_value(&a, &w, bias, &vec![0u32; n]);
        let mut prev = i64::MIN;
        for k in 1..=3u32 {
            let shifts: Vec<u32> = w
                .iter()
                .map(|&wi| axsum::product_bits(4, wi).saturating_sub(k))
                .collect();
            let v = axsum::neuron_value(&a, &w, bias, &shifts);
            check(v >= prev, format!("k={k}: {v} < {prev}"))?;
            check(v <= exact, format!("k={k}: {v} > exact {exact}"))?;
            prev = v;
        }
        Ok(())
    });
}

#[test]
fn derived_shifts_respect_k_ordering() {
    // derive_shifts with larger k never truncates more bits
    forall_seeded(0xC6, 20, |rng| {
        let q = rand_q(rng);
        let xs: Vec<Vec<i64>> = (0..30)
            .map(|_| (0..q.din()).map(|_| rng.range_i64(0, 15)).collect())
            .collect();
        let means = mean_activations(&q, &xs);
        let sig = significance(&q, &means);
        let g = vec![1e18, 1e18];
        let p1 = derive_shifts(&q, &sig, &g, 1);
        let p3 = derive_shifts(&q, &sig, &g, 3);
        // only layer 0 has fixed input widths; deeper layers' product
        // sizes shrink with the *upstream* truncation, so cross-k shift
        // comparisons are only meaningful at the primary inputs
        for (r1, r3) in p1.shifts[0].iter().zip(&p3.shifts[0]) {
            for (&s1, &s3) in r1.iter().zip(r3) {
                check(s3 <= s1, format!("s3={s3} > s1={s1}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn truncated_value_never_exceeds_exact_positive_part() {
    // truncation only discards magnitude: each product term shrinks
    forall_seeded(0xC3, 60, |rng| {
        let a = rng.range_i64(0, 15);
        let w = rng.range_i64(1, 127);
        let s = rng.below(10) as u32;
        let p = a * w;
        let t = (p >> s) << s;
        check(t <= p && t >= 0, format!("t={t} p={p}"))?;
        check(p - t < (1 << s), "truncation error bound")
    });
}

#[test]
fn widths_cover_all_reachable_values() {
    // layer_input_widths must bound every activation value reachable on
    // random inputs (the circuit sizes buses from these bounds)
    forall_seeded(0xC4, 20, |rng| {
        let q = rand_q(rng);
        let plan = rand_plan(rng, &q);
        let widths = axsum::layer_input_widths(&q, &plan);
        let mut scratch = Vec::new();
        for _ in 0..30 {
            let x: Vec<i64> = (0..q.din()).map(|_| rng.range_i64(0, 15)).collect();
            // hidden activations
            let mut acts = x.clone();
            let l = 0usize;
            let mut hidden = Vec::new();
            for (j, row) in q.w[l].iter().enumerate() {
                let v = axsum::neuron_value(&acts, row, q.b[l][j], &plan.shifts[l][j]).max(0);
                hidden.push(v);
            }
            acts = hidden;
            for (j, &v) in acts.iter().enumerate() {
                let w = widths[1][j];
                check(
                    (v as u64) < (1u64 << w),
                    format!("activation {v} overflows width {w}"),
                )?;
            }
            let _ = axsum::forward(&q, &plan, &x, &mut scratch);
        }
        Ok(())
    });
}

#[test]
fn verilog_emission_total_and_parseable_shape() {
    forall_seeded(0xC5, 10, |rng| {
        let q = rand_q(rng);
        let spec = MlpCircuitSpec::exact(
            "prop_v",
            q.w.clone(),
            q.b.clone(),
            4,
            NeuronStyle::AxSum,
        );
        let nl = build_mlp(&spec);
        let v = axmlp::verilog::to_verilog(&nl);
        check(v.contains("module prop_v"), "module header")?;
        check(v.contains("endmodule"), "endmodule")?;
        check(
            v.matches("assign").count() >= nl.n_cells(),
            "every cell emitted",
        )
    });
}

#[test]
fn failure_injection_bad_artifacts_are_graceful() {
    // a corrupt artifact directory must produce errors, not panics
    let dir = std::env::temp_dir().join("axmlp_bad_artifacts");
    let _ = std::fs::create_dir_all(&dir);
    std::fs::write(dir.join("topologies.json"), "{not json").unwrap();
    assert!(axmlp::runtime::Runtime::new(&dir).is_err());
    std::fs::write(
        dir.join("topologies.json"),
        r#"{"eval_batch":256,"train_batch":64,"vc_max":256,
            "topologies":[{"key":"zz","name":"Z","din":2,"hidden":2,"dout":2,
              "fwd":"missing.hlo.txt","train":"missing.hlo.txt"}]}"#,
    )
    .unwrap();
    let rt = axmlp::runtime::Runtime::new(&dir).unwrap();
    assert!(rt.load("missing.hlo.txt").is_err());
    let q = QuantMlp {
        w: vec![vec![vec![1, 1]; 2], vec![vec![1, 1]; 2]],
        b: vec![vec![0; 2], vec![0; 2]],
        in_bits: 4,
        w_scales: vec![1.0, 1.0],
    };
    let plan = ShiftPlan::exact(&q);
    assert!(rt
        .forward_logits("zz", &q, &plan, &[vec![0, 0]])
        .is_err());
}

// ---------------------------------------------------------------------------
// DSE selection properties (pareto_front / select_for_threshold).
// ---------------------------------------------------------------------------

/// Random synthetic design evaluations: quantized accuracies and areas so
/// ties (the delicate case for front extraction) actually occur.
fn rand_designs(rng: &mut Rng, n: usize) -> Vec<axmlp::dse::DesignEval> {
    (0..n)
        .map(|i| axmlp::dse::DesignEval {
            k: 1 + (i % 3) as u32,
            g: Vec::new(),
            plan: ShiftPlan { shifts: Vec::new() },
            acc_train: rng.below(21) as f64 / 20.0,
            acc_test: rng.f64(),
            costs: axmlp::estimate::Costs {
                area_mm2: (1 + rng.below(40)) as f64 * 0.5,
                power_mw: rng.f64() * 10.0,
                delay_ms: 1.0 + rng.f64(),
                cells: 1 + rng.below(100),
            },
        })
        .collect()
}

#[test]
fn pareto_front_is_mutually_nondominated_and_complete() {
    use axmlp::dse::pareto_front;
    forall_seeded(0xFA57, 80, |rng| {
        let n = 2 + rng.below(40);
        let designs = rand_designs(rng, n);
        let front = pareto_front(&designs, true);
        check(!front.is_empty(), "front must not be empty")?;
        // mutual non-domination: along the front (sorted by descending
        // accuracy) the area must strictly improve, so no member weakly
        // dominates another
        for w in front.windows(2) {
            let (a, b) = (&designs[w[0]], &designs[w[1]]);
            check(
                b.acc_train < a.acc_train + 1e-12,
                "front accuracy must be non-increasing",
            )?;
            check(
                b.costs.area_mm2 < a.costs.area_mm2,
                "front area must strictly decrease",
            )?;
            check(
                b.acc_train < a.acc_train,
                format!(
                    "equal-accuracy pair on front: {} / {}",
                    a.acc_train, b.acc_train
                ),
            )?;
        }
        // completeness: every design is weakly dominated by a front member
        for d in &designs {
            check(
                front.iter().any(|&f| {
                    designs[f].acc_train >= d.acc_train - 1e-12
                        && designs[f].costs.area_mm2 <= d.costs.area_mm2 + 1e-12
                }),
                "non-front design not covered by the front",
            )?;
        }
        Ok(())
    });
}

#[test]
fn select_for_threshold_monotone_in_budget() {
    use axmlp::dse::select_for_threshold;
    forall_seeded(0x5E1E, 80, |rng| {
        let n = 2 + rng.below(40);
        let designs = rand_designs(rng, n);
        let acc0 = designs
            .iter()
            .map(|d| d.acc_train)
            .fold(f64::NEG_INFINITY, f64::max);
        let mut prev_area: Option<f64> = None;
        // tightest to loosest: the selected area can only shrink as the
        // accuracy budget loosens, and never violates its own floor
        for t in [0.0, 0.01, 0.05, 0.1, 0.25, 0.5, 1.0] {
            match select_for_threshold(&designs, acc0, t) {
                Some(d) => {
                    check(
                        d.acc_train >= acc0 - t - 1e-9,
                        format!("selection violates floor at t={t}"),
                    )?;
                    if let Some(pa) = prev_area {
                        check(
                            d.costs.area_mm2 <= pa + 1e-12,
                            format!("area grew as budget loosened at t={t}"),
                        )?;
                    }
                    prev_area = Some(d.costs.area_mm2);
                }
                None => {
                    check(
                        prev_area.is_none(),
                        "selection disappeared as budget loosened",
                    )?;
                }
            }
        }
        // t=0 always selects (the best-accuracy design qualifies), so by
        // monotonicity every looser budget selected too
        check(prev_area.is_some(), "t=1.0 must select something")?;
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Conformance-harness satellites (ISSUE 3): sweep semantics, signed
// round-trips, product-width reference.
// ---------------------------------------------------------------------------

#[test]
fn netlist_sweep_is_semantics_preserving_on_fuzzed_netlists() {
    // dead-gate elimination must never change any output bus value on
    // any pattern — checked on raw randomly-built netlists (which carry
    // plenty of dead logic) before and after `Netlist::sweep`.
    use axmlp::conformance::gen::random_netlist;
    forall_seeded(0x5EE9, 40, |rng| {
        let pats = 70; // crosses the 64-pattern chunk edge
        let (raw, inputs) = random_netlist(rng, pats);
        let (swept, removed) = raw.sweep();
        check(swept.n_gates() <= raw.n_gates(), "sweep never adds gates")?;
        check(
            raw.n_cells() == swept.n_cells() + removed,
            "removed-count bookkeeping",
        )?;
        let before = simulate(&raw, &inputs, pats, false);
        let after = simulate(&swept, &inputs, pats, false);
        for bus in &raw.outputs {
            check_eq(
                before.outputs[&bus.name].clone(),
                after.outputs[&bus.name].clone(),
                &format!("bus {} diverged across sweep", bus.name),
            )?;
        }
        // idempotence: sweeping a swept netlist removes nothing more
        let (_, removed2) = swept.sweep();
        check_eq(removed2, 0, "sweep idempotent")
    });
}

#[test]
fn as_signed_roundtrips_twos_complement_for_all_widths() {
    use axmlp::sim::as_signed;
    for w in 1usize..=16 {
        let lo = -(1i64 << (w - 1));
        let hi = (1i64 << (w - 1)) - 1;
        // exhaustive for every width up to 16 bits
        for v in lo..=hi {
            let packed = (v as u64) & ((1u64 << w) - 1);
            assert_eq!(as_signed(packed, w), v, "w={w} v={v}");
        }
        // high garbage bits beyond the bus width must be masked off
        let mut rng = Rng::new(0xA5 ^ w as u64);
        for _ in 0..200 {
            let v = rng.range_i64(lo, hi);
            let packed = (v as u64) & ((1u64 << w) - 1);
            let garbage = rng.next_u64() << w;
            assert_eq!(as_signed(packed | garbage, w), v, "w={w} v={v} (garbage)");
        }
    }
}

#[test]
fn product_bits_matches_naive_i128_reference() {
    use axmlp::axsum::product_bits;
    // Eq. 5: n_i = $size(|w|) + $size(a). Reference recomputes both via
    // an i128 bit-length loop, and checks sufficiency: 2^n_i bounds the
    // largest reachable product (2^a_bits - 1) * |w|.
    fn bitlen(mut v: i128) -> u32 {
        let mut n = 0;
        while v > 0 {
            n += 1;
            v >>= 1;
        }
        n
    }
    forall_seeded(0xB175, 500, |rng| {
        let a_bits = 1 + rng.below(16);
        let w = rng.range_i64(-(1 << 20), 1 << 20);
        let got = product_bits(a_bits, w);
        let want = if w == 0 {
            0
        } else {
            bitlen(w.unsigned_abs() as i128) + a_bits as u32
        };
        check_eq(got, want, &format!("a_bits={a_bits} w={w}"))?;
        if w != 0 {
            let max_product = ((1i128 << a_bits) - 1) * (w.unsigned_abs() as i128);
            check(
                (1i128 << got) > max_product,
                format!("2^{got} does not bound {max_product}"),
            )?;
            // and it is within one bit of minimal
            check(
                got <= bitlen(max_product) + 1,
                format!("n_i={got} wasteful for max product {max_product}"),
            )?;
        }
        Ok(())
    });
}

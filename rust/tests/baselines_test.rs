//! Integration tests over the comparison baselines ([8] and [15]).

use axmlp::baselines::crosslayer::{circuit_accuracy, crosslayer_baseline};
use axmlp::baselines::stochastic::{sc_accuracy, sc_mlp_costs, ScConfig};
use axmlp::coordinator::{train_mlp0, PipelineConfig, SharedContext};
use axmlp::datasets;
use axmlp::fixed::{quantize, quantize_inputs};
use axmlp::synth::{build_mlp, MlpCircuitSpec, NeuronStyle};

#[test]
fn crosslayer_respects_budget_and_shrinks() {
    let ctx = SharedContext::new();
    let mut cfg = PipelineConfig::default();
    cfg.train.epochs = 60;
    let ds = datasets::load("v2", 2023).expect("dataset");
    let q0 = quantize(&train_mlp0(&ds, &cfg.train, 2023));
    let xq_train = quantize_inputs(&ds.x_train);
    let xq_test = quantize_inputs(&ds.x_test);
    let out = crosslayer_baseline(
        &q0, &xq_train, &ds.y_train, &xq_test, &ds.y_test,
        ctx.lut4(), &ctx.lib, 0.05, 64,
    );
    let acc0 = q0.accuracy_exact(&xq_train, &ds.y_train);
    assert!(out.acc_train >= acc0 - 0.05 - 1e-9);
    // must shrink vs the exact circuit of the same model
    let spec = MlpCircuitSpec::exact(
        "b", q0.w.clone(), q0.b.clone(), 4, NeuronStyle::ExactBespoke,
    );
    let nl = build_mlp(&spec);
    let base_area = axmlp::estimate::area_mm2(&nl, &ctx.lib);
    assert!(out.costs.area_mm2 < base_area, "{} !< {base_area}", out.costs.area_mm2);
    // sanity: the unmodified circuit classifies like the software model
    let acc_hw = circuit_accuracy(&nl, &xq_test, &ds.y_test);
    assert!((acc_hw - q0.accuracy_exact(&xq_test, &ds.y_test)).abs() < 1e-12);
}

#[test]
fn sc_baseline_costs_exceed_ours_shape() {
    // Fig. 9 shape: SC hardware is larger than the approximate bespoke
    // design (SNGs + counters dominate at these tiny topologies)
    let ctx = SharedContext::new();
    let cfg = ScConfig::default();
    for info in datasets::REGISTRY.iter().take(4) {
        let sc = sc_mlp_costs(info.din, info.hidden, info.dout, &ctx.lib, &cfg);
        assert!(sc.area_mm2 > 0.0);
        assert!(sc.delay_ms > 200.0, "stream length dominates delay");
    }
}

#[test]
fn sc_accuracy_degrades_vs_float() {
    let mut cfg_p = PipelineConfig::default();
    cfg_p.train.epochs = 80;
    let ds = datasets::load("se", 2023).expect("dataset");
    let mlp0 = train_mlp0(&ds, &cfg_p.train, 2023);
    let float_acc = mlp0.accuracy(&ds.x_test, &ds.y_test);
    let sc_cfg = ScConfig {
        stream_len: 512,
        ..Default::default()
    };
    let n = ds.x_test.len().min(120);
    let sc_acc = sc_accuracy(&mlp0, &ds.x_test[..n], &ds.y_test[..n], &sc_cfg);
    // SC noise should not *improve* accuracy; allow small sampling slack
    assert!(sc_acc <= float_acc + 0.05, "sc {sc_acc} vs float {float_acc}");
    assert!(sc_acc > 1.0 / ds.n_classes() as f64, "sc above chance");
}

//! Integration tests for the differential conformance harness (ISSUE 3):
//! fuzzed netlist↔software cross-validation must be clean on healthy
//! code, fault injection must be caught and shrunk to a reproducer
//! naming the layer/neuron, and the whole run must be deterministic.

use axmlp::axsum::{product_bits, ShiftPlan};
use axmlp::conformance::{self, gen, ConformConfig, TopologyRange};
use axmlp::fixed::QuantMlp;
use axmlp::util::json::Json;
use axmlp::util::rng::Rng;

#[test]
fn fuzz_run_is_clean_across_all_engines_and_plan_families() {
    let cfg = ConformConfig {
        cases: 64,
        seed: 2023,
        ..Default::default()
    };
    let report = conformance::run_fuzz(&cfg);
    assert!(
        report.ok(),
        "fuzz found engine divergence:\n{}",
        report
            .mismatches
            .iter()
            .map(|m| m.summary())
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert_eq!(report.cases, 64);
    assert!(report.plan_counts.iter().all(|&c| c > 0));
    // chunk-edge pattern counts were exercised (63..129 cycle)
    assert!(report.patterns_total >= 64 * 63);
}

#[test]
fn every_chunk_edge_pattern_count_agrees() {
    // one fixed model × plan evaluated at every 64-pattern chunk edge —
    // pins the packed simulator's boundary handling at the logit level
    let mut rng = Rng::new(77);
    let q = gen::random_quant_mlp(&mut rng, &TopologyRange::default());
    let xs_all = gen::mixed_stimulus(&mut rng, &q, 129);
    let (_, plan) = gen::random_plan(&mut rng, &q, &xs_all);
    for n in [1usize, 63, 64, 65, 127, 128, 129] {
        assert!(
            conformance::check_case(&q, &plan, &xs_all[..n]).is_none(),
            "divergence at {n} patterns"
        );
    }
}

#[test]
fn corrupting_one_shift_fails_with_reproducer_naming_the_neuron() {
    // acceptance criterion: deliberately corrupting one shift in a
    // ShiftPlan makes the harness fail with a shrunk reproducer naming
    // the layer/neuron
    let q = QuantMlp {
        w: vec![
            vec![vec![11, -6, 4], vec![2, 9, -7]],
            vec![vec![5, -3], vec![-2, 8]],
        ],
        b: vec![vec![4, -2], vec![0, 1]],
        in_bits: 4,
        w_scales: vec![1.0, 1.0],
    };
    let sw = ShiftPlan::exact(&q);
    let mut hw = sw.clone();
    // corrupt layer 1, neuron 0, product 1 (weight -3): zero it in HW
    hw.shifts[1][0][1] = product_bits(8, -3) + 4; // >= any reachable width
    let xs = gen::adversarial_stimulus(3, 4);
    let failure =
        conformance::check_case_pair(&q, &sw, &hw, &xs).expect("corruption must be detected");
    let shrunk = conformance::shrink(&q, &sw, &hw, &sw, &xs, failure);
    assert!(
        shrunk.kept_neurons[1].contains(&0),
        "reproducer must name L1 neuron 0: {}",
        shrunk.summary()
    );
    assert_eq!(shrunk.xs.len(), 1, "stimulus minimized to one pattern");
    assert!(shrunk.summary().contains("L1:"), "{}", shrunk.summary());
    // the reproducer is machine-readable and round-trips through JSON
    let j = shrunk.to_json();
    let re = Json::parse(&j.pretty()).expect("reproducer is valid JSON");
    assert!(re.get("layers").is_some());
    assert!(re.req_str("failure").is_ok());
}

#[test]
fn canary_is_part_of_the_instrument() {
    for site in conformance::FaultSite::ALL {
        let s = conformance::canary_at(7, site).unwrap_or_else(|e| {
            panic!("{} canary fires: {e}", site.name());
        });
        assert!(
            conformance::check_case_all(&s.q, &s.plan_sw, &s.plan_hw, &s.plan_bs, &s.xs)
                .is_some(),
            "{} canary reproducer must still fail",
            site.name()
        );
    }
}

#[test]
fn fuzz_report_deterministic_and_seeds_replayable() {
    let cfg = ConformConfig {
        cases: 16,
        seed: 5,
        ..Default::default()
    };
    let a = conformance::run_fuzz(&cfg);
    let b = conformance::run_fuzz(&cfg);
    assert_eq!(a.cases, 16);
    assert_eq!(a.plan_counts, b.plan_counts);
    assert_eq!(a.patterns_total, b.patterns_total);
    assert_eq!(a.failing, b.failing);
    // replaying a case seed regenerates the same model
    let mut r1 = Rng::new(conformance::case_seed(5, 3));
    let mut r2 = Rng::new(conformance::case_seed(5, 3));
    let q1 = gen::random_quant_mlp(&mut r1, &cfg.topology);
    let q2 = gen::random_quant_mlp(&mut r2, &cfg.topology);
    assert_eq!(q1.w, q2.w);
    assert_eq!(q1.b, q2.b);
}

//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These require `make artifacts` to have run; they skip (with a notice)
//! when the artifacts directory is absent so `cargo test` stays green in a
//! fresh checkout.

use axmlp::axsum::{self, ShiftPlan};
use axmlp::fixed::QuantMlp;
use axmlp::retrain::{backend_rust::RustBackend, RetrainState, TrainBackend};
use axmlp::runtime::{backend_pjrt::PjrtBackend, Runtime};
use axmlp::util::rng::Rng;
use axmlp::util::stats::argmax_f64;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("topologies.json").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Runtime::new(dir).expect("runtime init"))
}

fn rand_q(rng: &mut Rng, din: usize, hidden: usize, dout: usize) -> QuantMlp {
    QuantMlp {
        w: vec![
            (0..hidden)
                .map(|_| (0..din).map(|_| rng.range_i64(-100, 100)).collect())
                .collect(),
            (0..dout)
                .map(|_| (0..hidden).map(|_| rng.range_i64(-100, 100)).collect())
                .collect(),
        ],
        b: vec![
            (0..hidden).map(|_| rng.range_i64(-50, 50)).collect(),
            (0..dout).map(|_| rng.range_i64(-50, 50)).collect(),
        ],
        in_bits: 4,
        w_scales: vec![1.0, 1.0],
    }
}

#[test]
fn smoke_artifact_runs() {
    let Some(rt) = runtime_or_skip() else { return };
    rt.smoke().expect("smoke numerics");
    assert_eq!(rt.platform(), "cpu");
}

#[test]
fn fwd_artifact_bit_matches_rust_axsum_model() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(42);
    for key in ["ma", "v2", "bs"] {
        let top = rt.index.by_key(key).expect("topology in index");
        let q = rand_q(&mut rng, top.din, top.hidden, top.dout);
        // random truncation plan
        let mut plan = ShiftPlan::exact(&q);
        for layer in plan.shifts.iter_mut() {
            for row in layer.iter_mut() {
                for s in row.iter_mut() {
                    *s = rng.below(5) as u32;
                }
            }
        }
        let xs: Vec<Vec<i64>> = (0..300)
            .map(|_| (0..top.din).map(|_| rng.range_i64(0, 15)).collect())
            .collect();
        let logits = rt.forward_logits(key, &q, &plan, &xs).expect("fwd exec");
        assert_eq!(logits.len(), xs.len());
        let mut scratch = Vec::new();
        for (x, l) in xs.iter().zip(&logits) {
            let want = axsum::forward(&q, &plan, x, &mut scratch);
            let got: Vec<i64> = l.iter().map(|&v| v as i64).collect();
            assert_eq!(got, want, "key={key} x={x:?}");
        }
    }
}

#[test]
fn fwd_artifact_accuracy_equals_software_accuracy() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(7);
    let top = rt.index.by_key("v2").unwrap();
    let q = rand_q(&mut rng, top.din, top.hidden, top.dout);
    let plan = ShiftPlan::exact(&q);
    let xs: Vec<Vec<i64>> = (0..500)
        .map(|_| (0..top.din).map(|_| rng.range_i64(0, 15)).collect())
        .collect();
    let ys: Vec<usize> = xs.iter().map(|x| axsum::predict(&q, &plan, x)).collect();
    let acc_hw = rt.accuracy("v2", &q, &plan, &xs, &ys).unwrap();
    assert!((acc_hw - 1.0).abs() < 1e-12, "acc={acc_hw}");
}

#[test]
fn pjrt_train_step_descends_and_projects() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(9);
    let top = rt.index.by_key("ma").unwrap();
    let q = rand_q(&mut rng, top.din, top.hidden, top.dout);
    // synthetic labeled data from a teacher model
    let xs: Vec<Vec<i64>> = (0..256)
        .map(|_| (0..top.din).map(|_| rng.range_i64(0, 15)).collect())
        .collect();
    let plan = ShiftPlan::exact(&q);
    let ys: Vec<usize> = xs.iter().map(|x| axsum::predict(&q, &plan, x)).collect();

    let mut st = RetrainState::from_quant(&q, &xs, &ys, rt.index.train_batch, 11);
    let vc: Vec<f32> = (-127..=127).map(|v| v as f32).collect();
    let mut be = PjrtBackend::new(&rt, "ma").expect("backend");
    let s0 = be.train_epoch(&mut st, &vc, 0.5).expect("epoch");
    let mut last = s0.loss;
    for _ in 0..4 {
        last = be.train_epoch(&mut st, &vc, 0.5).expect("epoch").loss;
    }
    assert!(
        last <= s0.loss + 0.05,
        "loss should not blow up: {last} vs {}",
        s0.loss
    );

    // projection containment with a sparse VC
    let vc_sparse: Vec<f32> = vec![0.0, 1.0, -1.0, 2.0, -2.0, 4.0, -4.0, 8.0, -8.0,
                                   16.0, -16.0, 32.0, -32.0, 64.0, -64.0];
    be.train_epoch(&mut st, &vc_sparse, 0.5).unwrap();
    let qp = st.to_quant(&vc_sparse, &q);
    let allowed: Vec<i64> = vc_sparse.iter().map(|&v| v as i64).collect();
    for layer in &qp.w {
        for row in layer {
            for &w in row {
                assert!(allowed.contains(&w), "w={w} outside VC");
            }
        }
    }
}

#[test]
fn pjrt_and_rust_backends_agree_on_dynamics() {
    // Same state, same data, same lr: the two backends are independent
    // implementations of the same step; they should track each other in
    // loss trajectory and end accuracy (not bit-identical: shuffles and
    // float summation orders differ).
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(13);
    let top = rt.index.by_key("v2").unwrap();
    let teacher = rand_q(&mut rng, top.din, top.hidden, top.dout);
    let plan = ShiftPlan::exact(&teacher);
    let xs: Vec<Vec<i64>> = (0..384)
        .map(|_| (0..top.din).map(|_| rng.range_i64(0, 15)).collect())
        .collect();
    let ys: Vec<usize> = xs.iter().map(|x| axsum::predict(&teacher, &plan, x)).collect();
    // student starts perturbed
    let mut student = teacher.clone();
    for row in student.w[0].iter_mut() {
        for w in row.iter_mut() {
            *w = (*w + 17).clamp(-127, 127);
        }
    }
    let vc: Vec<f32> = (-127..=127).map(|v| v as f32).collect();

    let run = |backend: &mut dyn TrainBackend| -> (f64, f64) {
        let mut st = RetrainState::from_quant(&student, &xs, &ys, rt.index.train_batch, 17);
        let mut last_loss = f64::INFINITY;
        for _ in 0..6 {
            last_loss = backend.train_epoch(&mut st, &vc, 1.0).unwrap().loss;
        }
        let qf = st.to_quant(&vc, &student);
        (last_loss, qf.accuracy_exact(&xs, &ys))
    };
    let (l_rust, a_rust) = run(&mut RustBackend);
    let mut pjrt = PjrtBackend::new(&rt, "v2").unwrap();
    let (l_pjrt, a_pjrt) = run(&mut pjrt);
    // the native backend is a bit-faithful mirror of the AOT'd jax step:
    // same permutation, same batches, near-identical float math
    assert!(
        (a_rust - a_pjrt).abs() < 1e-9,
        "backends diverged: rust acc {a_rust}, pjrt acc {a_pjrt}"
    );
    assert!(
        (l_rust - l_pjrt).abs() < 1e-2 * l_rust.abs().max(1.0),
        "loss diverged: {l_rust} vs {l_pjrt}"
    );
}

#[test]
fn argmax_helper_consistent() {
    // guards the accuracy() reduction used on artifact logits
    let logits = [0.1f64, 0.9, 0.5];
    assert_eq!(argmax_f64(&logits), 1);
}

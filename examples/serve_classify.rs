//! Scenario: deployed-inference service loop. After co-design, the same
//! AOT artifact that drove retraining serves batched classification
//! requests through PJRT — the Rust binary is the complete serving stack
//! (Python never runs). Reports end-to-end batch latency and throughput.
//!
//! ```text
//! make artifacts && cargo run --release --example serve_classify -- [dataset-key] [n-requests]
//! ```

use std::time::Instant;

use axmlp::axsum::ShiftPlan;
use axmlp::coordinator::train_mlp0;
use axmlp::coordinator::PipelineConfig;
use axmlp::datasets;
use axmlp::fixed::{quantize, quantize_inputs};
use axmlp::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let key = std::env::args().nth(1).unwrap_or_else(|| "pd".to_string());
    let n_req: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4096);

    let rt = Runtime::new(Runtime::default_dir())?;
    let ds = datasets::load(&key, 2023)?;
    let cfg = PipelineConfig::default();
    let q = quantize(&train_mlp0(&ds, &cfg.train, 2023));
    let plan = ShiftPlan::exact(&q);

    // synthesize a request stream by cycling the test set
    let xq = quantize_inputs(&ds.x_test);
    let requests: Vec<Vec<i64>> = (0..n_req).map(|i| xq[i % xq.len()].clone()).collect();
    let labels: Vec<usize> = (0..n_req).map(|i| ds.y_test[i % ds.y_test.len()]).collect();

    // warm-up compiles + caches the executable
    let _ = rt.forward_logits(&key, &q, &plan, &requests[..rt.index.eval_batch.min(n_req)])?;

    let t0 = Instant::now();
    let acc = rt.accuracy(&key, &q, &plan, &requests, &labels)?;
    let dt = t0.elapsed();
    let per_batch = dt.as_secs_f64() / (n_req as f64 / rt.index.eval_batch as f64);
    println!(
        "served {n_req} requests for {} via PJRT: acc {:.3}, {:.2} ms/batch({}), {:.0} req/s",
        ds.info.name,
        acc,
        per_batch * 1e3,
        rt.index.eval_batch,
        n_req as f64 / dt.as_secs_f64()
    );
    Ok(())
}

//! Quickstart: co-design one printed MLP end to end in ~a second.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the full public API on the Mammographic dataset: train MLP0,
//! quantize, synthesize the exact bespoke baseline, retrain
//! printing-friendly coefficients (PJRT artifact backend when available),
//! run the AxSum DSE, and print the chosen design + its battery class.

use axmlp::coordinator::{run_dataset, PipelineConfig, SharedContext};
use axmlp::datasets;
use axmlp::retrain::backend_rust::RustBackend;
use axmlp::runtime::{backend_pjrt::PjrtBackend, Runtime};

fn main() -> anyhow::Result<()> {
    let ds = datasets::load("ma", 2023)?;
    println!(
        "dataset: {} ({} train / {} test, {} features, {} classes)",
        ds.info.name,
        ds.x_train.len(),
        ds.x_test.len(),
        ds.n_features(),
        ds.n_classes()
    );

    let mut cfg = PipelineConfig::default();
    cfg.thresholds = vec![0.01];
    cfg.dse.max_g_levels = 5;

    let ctx = SharedContext::new();
    // prefer the production PJRT path; fall back to the native mirror
    let outcome = match Runtime::new(Runtime::default_dir()) {
        Ok(rt) => {
            println!("backend: pjrt ({} artifacts)", rt.index.topologies.len());
            let mut be = PjrtBackend::new(&rt, "ma")?;
            run_dataset(&ds, &cfg, &ctx, &mut be)?
        }
        Err(e) => {
            println!("backend: rust (no artifacts: {e})");
            run_dataset(&ds, &cfg, &ctx, &mut RustBackend)?
        }
    };

    println!("\nbaseline  (exact bespoke [2]):");
    println!(
        "  acc {:.3} | {:.2} cm² | {:.1} mW | CPD {:.0} ms | battery: {}",
        outcome.q0_acc_test,
        outcome.baseline_costs.area_cm2(),
        outcome.baseline_costs.power_mw,
        outcome.baseline_costs.delay_ms,
        outcome.baseline_battery.name(),
    );
    let t = &outcome.thresholds[0];
    println!("ours (retrain + AxSum, T = 1%):");
    println!(
        "  acc {:.3} | {:.2} cm² | {:.1} mW | CPD {:.0} ms | battery: {}",
        t.design.acc_test,
        t.design.costs.area_cm2(),
        t.design.costs.power_mw,
        t.design.costs.delay_ms,
        t.battery.name(),
    );
    println!(
        "  gains: {:.1}x area, {:.1}x power (clusters used: C0..C{})",
        t.area_gain,
        t.power_gain,
        t.clusters_used - 1
    );
    Ok(())
}

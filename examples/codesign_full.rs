//! End-to-end validation driver (DESIGN.md §6 "E2E"): run the complete
//! co-design pipeline — MLP0 training, quantization, baseline synthesis,
//! PJRT-driven printing-friendly retraining, AxSum DSE, Pareto selection —
//! on **all ten paper datasets**, verify every layer composes, and report
//! the paper's headline metric (average area/power reduction vs the exact
//! bespoke baseline at <=1% accuracy loss) plus the battery-feasibility
//! flip. The run is recorded in EXPERIMENTS.md.
//!
//! ```text
//! make artifacts && cargo run --release --example codesign_full
//! ```

use axmlp::experiments::{exp_fig6, ExpConfig};
use axmlp::util::stats::geo_mean;

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let cfg = ExpConfig::default();
    let outcomes = exp_fig6(&cfg)?;

    // headline: average gains at the 1% threshold
    let mut area = Vec::new();
    let mut power = Vec::new();
    let mut within = 0usize;
    let mut powerable = 0usize;
    for o in &outcomes {
        let t = &o.thresholds[0];
        area.push(t.area_gain);
        power.push(t.power_gain);
        if t.design.acc_train >= o.q0_acc_train - t.threshold - 1e-9 {
            within += 1;
        }
        let any_batt = o
            .thresholds
            .iter()
            .any(|t| t.battery != axmlp::battery::Battery::None);
        if any_batt {
            powerable += 1;
        }
    }
    println!("\n==================== E2E SUMMARY ====================");
    println!("datasets processed:        {}", outcomes.len());
    println!("threshold satisfied (1%):  {within}/{}", outcomes.len());
    println!(
        "avg area gain @1% (geo):   {:.1}x   (paper: 6.0x)",
        geo_mean(&area)
    );
    println!(
        "avg power gain @1% (geo):  {:.1}x   (paper: 5.7x)",
        geo_mean(&power)
    );
    println!(
        "battery-powerable:         {powerable}/{} (paper: 9/10, baseline 2/10)",
        outcomes.len()
    );
    println!("wall clock:                {:.1}s", t0.elapsed().as_secs_f64());
    println!("(per-figure CSVs under results/)");
    anyhow::ensure!(within == outcomes.len(), "a dataset missed its threshold");
    Ok(())
}

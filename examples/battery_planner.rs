//! Scenario: smart-packaging battery planning (the paper's Fig. 8 use
//! case, §1: FMCG / disposables / low-end healthcare).
//!
//! A product team has a printed battery budget per SKU and needs to know,
//! per classification task, the loosest accuracy budget that fits it.
//! Sweeps accuracy-loss thresholds and reports the cheapest battery tier
//! each one unlocks.
//!
//! ```text
//! cargo run --release --example battery_planner -- [dataset-key] [budget-mW]
//! ```

use axmlp::battery::classify;
use axmlp::coordinator::{run_dataset, PipelineConfig, SharedContext};
use axmlp::datasets;
use axmlp::retrain::backend_rust::RustBackend;
use axmlp::runtime::{backend_pjrt::PjrtBackend, Runtime};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let key = args.first().map(|s| s.as_str()).unwrap_or("v3");
    let budget_mw: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(15.0);
    anyhow::ensure!(
        axmlp::datasets::registry::by_key(key).is_some(),
        "unknown dataset `{key}`"
    );

    let ds = datasets::load(key, 2023)?;
    let mut cfg = PipelineConfig::default();
    cfg.thresholds = vec![0.005, 0.01, 0.02, 0.05, 0.10];
    cfg.dse.max_g_levels = 6;
    let ctx = SharedContext::new();

    let outcome = match Runtime::new(Runtime::default_dir()) {
        Ok(rt) => {
            let mut be = PjrtBackend::new(&rt, key)?;
            run_dataset(&ds, &cfg, &ctx, &mut be)?
        }
        Err(_) => run_dataset(&ds, &cfg, &ctx, &mut RustBackend)?,
    };

    println!(
        "battery planning for {} (budget {budget_mw} mW); baseline draws {:.1} mW ({})",
        ds.info.name,
        outcome.baseline_costs.power_mw,
        outcome.baseline_battery.name()
    );
    println!("{:<10} {:>10} {:>10} {:>10}  {:<16} fits?", "T", "acc", "cm²", "mW", "battery");
    let mut first_fit: Option<f64> = None;
    for t in &outcome.thresholds {
        let fits = t.design.costs.power_mw <= budget_mw;
        if fits && first_fit.is_none() {
            first_fit = Some(t.threshold);
        }
        println!(
            "{:<10} {:>10.3} {:>10.2} {:>10.1}  {:<16} {}",
            format!("{:.1}%", t.threshold * 100.0),
            t.design.acc_test,
            t.design.costs.area_cm2(),
            t.design.costs.power_mw,
            classify(t.design.costs.power_mw).name(),
            if fits { "yes" } else { "no" },
        );
    }
    match first_fit {
        Some(t) => println!("\n→ ship it with T = {:.1}% accuracy budget", t * 100.0),
        None => println!("\n→ no design fits {budget_mw} mW; consider a larger battery tier"),
    }
    Ok(())
}

//! Scenario: RTL hand-off. Generates the bespoke Verilog for a co-designed
//! MLP (what the paper's framework feeds to the EDA flow), plus a
//! simulation-backed equivalence check between the emitted netlist and the
//! bit-exact software model.
//!
//! ```text
//! cargo run --release --example verilog_export -- [dataset-key]
//! ```

use axmlp::coordinator::{run_dataset, PipelineConfig, SharedContext};
use axmlp::datasets;
use axmlp::fixed::quantize_inputs;
use axmlp::retrain::backend_rust::RustBackend;
use axmlp::synth::{build_mlp, MlpCircuitSpec, NeuronStyle};

fn main() -> anyhow::Result<()> {
    let key = std::env::args().nth(1).unwrap_or_else(|| "se".to_string());
    let ds = datasets::load(&key, 2023)?;
    let mut cfg = PipelineConfig::default();
    cfg.thresholds = vec![0.02];
    cfg.dse.max_g_levels = 4;
    let ctx = SharedContext::new();
    let outcome = run_dataset(&ds, &cfg, &ctx, &mut RustBackend)?;
    let t = &outcome.thresholds[0];

    let spec = MlpCircuitSpec {
        name: format!("axmlp_{key}"),
        weights: t.model.w.clone(),
        biases: t.model.b.clone(),
        shifts: t.design.plan.shifts.clone(),
        in_bits: t.model.in_bits,
        style: NeuronStyle::AxSum,
    };
    let nl = build_mlp(&spec);

    // equivalence check: simulate the emitted netlist on the test set
    let xq = quantize_inputs(&ds.x_test);
    let mut inputs = std::collections::HashMap::new();
    for i in 0..t.model.din() {
        inputs.insert(format!("x{i}"), xq.iter().map(|x| x[i] as u64).collect::<Vec<u64>>());
    }
    let sim = axmlp::sim::simulate(&nl, &inputs, xq.len(), false);
    let mut mismatches = 0;
    for (x, &cls) in xq.iter().zip(&sim.outputs["class"]) {
        if axmlp::axsum::predict(&t.model, &t.design.plan, x) != cls as usize {
            mismatches += 1;
        }
    }
    anyhow::ensure!(mismatches == 0, "netlist/software mismatch x{mismatches}");

    let v = axmlp::verilog::to_verilog(&nl);
    std::fs::create_dir_all("results")?;
    let path = format!("results/axmlp_{key}.v");
    std::fs::write(&path, &v)?;
    // self-checking testbench over the first 32 test vectors
    let tb_stim: Vec<Vec<i64>> = xq.iter().take(32).cloned().collect();
    let tb_exp: Vec<usize> = tb_stim
        .iter()
        .map(|x| axmlp::axsum::predict(&t.model, &t.design.plan, x))
        .collect();
    let tb = axmlp::verilog::to_testbench(&nl, &tb_stim, &tb_exp);
    std::fs::write(format!("results/axmlp_{key}_tb.v"), &tb)?;
    println!(
        "wrote {path} (+_tb.v): {} cells, {:.2} cm², {:.1} mW, acc(test) {:.3} — netlist ≡ software on {} vectors",
        nl.n_cells(),
        t.design.costs.area_cm2(),
        t.design.costs.power_mw,
        t.design.acc_test,
        xq.len()
    );
    Ok(())
}

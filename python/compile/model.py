"""L2 — JAX compute graphs for the co-design framework.

Two graphs are AOT-lowered per paper topology (see aot.py):

  * `mlp_fwd_axsum`  — the quantized AxSum inference forward used by the
    Rust DSE/eval path. It calls the L1 Pallas kernel, so the kernel lowers
    into the same HLO artifact. With all shifts = 0 it degrades to the
    *exact* bespoke forward, so one artifact serves both exact-accuracy
    evaluation and approximate-design evaluation.

  * `train_step` — one minibatch step of the printing-friendly retraining
    (paper Algorithm 1): straight-through-estimator projection of the
    coefficients onto the allowed value set VC (the union of the coefficient
    clusters consumed so far), SGD on softmax cross-entropy, and a count of
    coefficients whose projection changed (the Rust driver uses it for the
    adaptive learning-rate rule: "if no coefficient updated -> increase
    learning rate").

Everything runs in the *integer coefficient domain*: activations are
integer-valued f32 (primary inputs quantized to [0, 15]), coefficients live
in [-127, 127]. The softmax temperature input rescales integer-domain
logits back to float-model magnitudes for a well-conditioned loss.
"""

import jax
import jax.numpy as jnp

from .kernels.axsum import axsum_layer
from .topologies import W_MAX


def mlp_fwd_axsum(x, w1, b1, s1, w2, b2, s2, *, block_b=64, interpret=True):
    """AxSum quantized forward (integer domain): returns logits [B, Dout]."""
    h = axsum_layer(x, w1, b1, s1, block_b=block_b, interpret=interpret)
    h = jnp.maximum(h, 0.0)
    o = axsum_layer(h, w2, b2, s2, block_b=block_b, interpret=interpret)
    return (o,)


def project_vc(w, vc, vc_mask):
    """Map each coefficient to its closest allowed value in VC.

    vc: [VC_MAX] candidate values, vc_mask: [VC_MAX] 1.0 for valid slots.
    Ties resolve to the lowest index (jnp.argmin), i.e. the value the Rust
    driver ordered first — it emits VC sorted by cluster then magnitude so
    ties prefer cheaper coefficients.
    """
    d = jnp.abs(w[..., None] - vc) + (1.0 - vc_mask) * 1e9
    idx = jnp.argmin(d, axis=-1)
    return vc[idx]


def _ste(w, vc, vc_mask):
    """Straight-through estimator: forward uses proj(w), grad flows to w."""
    return w + jax.lax.stop_gradient(project_vc(w, vc, vc_mask) - w)


def _loss_fn(params, x, y1h, vc, vc_mask, temp):
    w1, b1, w2, b2 = params
    w1q = _ste(w1, vc, vc_mask)
    w2q = _ste(w2, vc, vc_mask)
    h = jnp.maximum(x @ w1q + b1[None, :], 0.0)
    logits = (h @ w2q + b2[None, :]) / temp
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y1h * logp, axis=-1))


def train_step(w1, b1, w2, b2, x, y1h, vc, vc_mask, lr, temp):
    """One SGD step of printing-friendly retraining.

    Returns (w1', b1', w2', b2', w1q, w2q, loss, changed) where w?q are the
    projected (hardware) coefficients after the update and `changed` counts
    coefficients whose projection moved this step.
    """
    params = (w1, b1, w2, b2)
    loss, grads = jax.value_and_grad(_loss_fn)(params, x, y1h, vc, vc_mask, temp)
    p1o = project_vc(w1, vc, vc_mask)
    p2o = project_vc(w2, vc, vc_mask)
    w1n = jnp.clip(w1 - lr * grads[0], -float(W_MAX), float(W_MAX))
    b1n = b1 - lr * grads[1]
    w2n = jnp.clip(w2 - lr * grads[2], -float(W_MAX), float(W_MAX))
    b2n = b2 - lr * grads[3]
    p1n = project_vc(w1n, vc, vc_mask)
    p2n = project_vc(w2n, vc, vc_mask)
    changed = jnp.sum(p1n != p1o) + jnp.sum(p2n != p2o)
    return (w1n, b1n, w2n, b2n, p1n, p2n, loss, changed.astype(jnp.float32))


def float_fwd(x, w1, b1, w2, b2):
    """Plain float forward (reference model, used in python tests only)."""
    h = jnp.maximum(x @ w1 + b1[None, :], 0.0)
    return h @ w2 + b2[None, :]

"""Paper Table 2 dataset/topology registry (single source of truth).

Each entry is (key, full name, #inputs, #hidden, #outputs). The topologies
are exactly the paper's Table 2 `#input x L x #output` MLPs. The same table
is mirrored on the Rust side in `rust/src/datasets/registry.rs`; the AOT
step additionally dumps `artifacts/topologies.json` so the Rust coordinator
never hardcodes shapes.
"""

# key, name, d_in, hidden, d_out, #MACs (paper), paper test accuracy
TOPOLOGIES = [
    ("ww", "WhiteWine", 11, 4, 7, 72, 0.54),
    ("ca", "Cardio", 21, 3, 3, 72, 0.88),
    ("rw", "RedWine", 11, 2, 6, 34, 0.56),
    ("pd", "Pendigits", 16, 5, 10, 130, 0.94),
    ("v3", "VertebralColumn3C", 6, 3, 3, 27, 0.83),
    ("bs", "BalanceScale", 4, 3, 3, 21, 0.91),
    ("se", "Seeds", 7, 3, 3, 30, 0.94),
    ("bc", "BreastCancer", 9, 3, 2, 33, 0.98),
    ("v2", "VertebralColumn2C", 6, 3, 2, 24, 0.90),
    ("ma", "Mammographic", 5, 3, 2, 21, 0.86),
]

# Fixed batch sizes baked into the AOT artifacts. The Rust side pads the
# final partial batch with zero rows and ignores the padded logits.
EVAL_BATCH = 256
TRAIN_BATCH = 64

# Maximum number of candidate coefficient values passed to the train-step
# artifact: 0 plus +/-w for w in [1,127] plus -128 is 256; padded to a
# round 256. Unused slots are masked out.
VC_MAX = 256

# Input activation precision (paper Section 3.1: 4-bit inputs in [0,1]).
INPUT_BITS = 4
A_MAX = (1 << INPUT_BITS) - 1  # 15

# Coefficient precision (paper: up to 8 bits, w in [-128, 127]; retraining
# uses +/- of positive cluster values so the effective range is symmetric).
COEFF_BITS = 8
W_MAX = 127


def by_key(key):
    for t in TOPOLOGIES:
        if t[0] == key:
            return t
    raise KeyError(key)

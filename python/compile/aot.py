"""AOT lowering: JAX (L2, embedding the L1 Pallas kernel) -> HLO text.

Emits, per paper topology, two PJRT-loadable artifacts plus a metadata
index consumed by the Rust coordinator:

    artifacts/fwd_<key>.hlo.txt    quantized AxSum inference forward
    artifacts/train_<key>.hlo.txt  one printing-friendly retraining step
    artifacts/smoke.hlo.txt        trivial graph for runtime smoke tests
    artifacts/topologies.json      shapes + batch sizes + file names

Interchange is HLO **text**, not `.serialize()`: jax >= 0.5 serializes
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` crate binds) rejects; the text parser reassigns
ids and round-trips cleanly. Lowered with return_tuple=True; the Rust side
unwraps the tuple.

Python runs only here (`make artifacts`); the Rust binary is self-contained
afterwards.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import mlp_fwd_axsum, train_step
from .topologies import (EVAL_BATCH, TOPOLOGIES, TRAIN_BATCH, VC_MAX)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), jnp.float32)


def lower_fwd(din, hidden, dout, batch=EVAL_BATCH, block_b=64):
    fwd = functools.partial(mlp_fwd_axsum, block_b=block_b, interpret=True)
    return jax.jit(fwd).lower(
        _spec(batch, din),          # x (integer-valued)
        _spec(din, hidden),         # w1
        _spec(hidden),              # b1
        _spec(din, hidden),         # s1 (truncation shifts)
        _spec(hidden, dout),        # w2
        _spec(dout),                # b2
        _spec(hidden, dout),        # s2
    )


def lower_train(din, hidden, dout, batch=TRAIN_BATCH):
    return jax.jit(train_step).lower(
        _spec(din, hidden),         # w1 shadow
        _spec(hidden),              # b1
        _spec(hidden, dout),        # w2 shadow
        _spec(dout),                # b2
        _spec(batch, din),          # x (integer-valued)
        _spec(batch, dout),         # y one-hot
        _spec(VC_MAX),              # vc candidates
        _spec(VC_MAX),              # vc mask
        _spec(),                    # lr
        _spec(),                    # temp
    )


def lower_smoke():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = _spec(2, 2)
    return jax.jit(fn).lower(spec, spec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated topology keys (default: all)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None

    with open(os.path.join(args.out, "smoke.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lower_smoke()))
    print("wrote smoke.hlo.txt")

    index = {
        "eval_batch": EVAL_BATCH,
        "train_batch": TRAIN_BATCH,
        "vc_max": VC_MAX,
        "topologies": [],
    }
    for key, name, din, hidden, dout, _macs, _acc in TOPOLOGIES:
        if only and key not in only:
            continue
        fwd_file = f"fwd_{key}.hlo.txt"
        train_file = f"train_{key}.hlo.txt"
        with open(os.path.join(args.out, fwd_file), "w") as f:
            f.write(to_hlo_text(lower_fwd(din, hidden, dout)))
        with open(os.path.join(args.out, train_file), "w") as f:
            f.write(to_hlo_text(lower_train(din, hidden, dout)))
        index["topologies"].append({
            "key": key, "name": name,
            "din": din, "hidden": hidden, "dout": dout,
            "fwd": fwd_file, "train": train_file,
        })
        print(f"wrote {fwd_file} + {train_file} ({name})")

    with open(os.path.join(args.out, "topologies.json"), "w") as f:
        json.dump(index, f, indent=1)
    print(f"wrote topologies.json ({len(index['topologies'])} topologies)")


if __name__ == "__main__":
    main()

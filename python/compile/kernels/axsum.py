"""L1 — Pallas kernel for the AxSum approximate neuron layer.

The paper's compute hot-spot is the bespoke neuron (Fig. 4): split-sign
product accumulation with per-product MSB truncation and 1's-complement
negation of the negative tree. This kernel evaluates one whole layer for a
batch tile.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the weight, shift and
sign-mask tiles are tiny (<= 21x10 for every paper topology) and live in
VMEM for the whole grid; the batch dimension is streamed in tiles of
`block_b` rows. Truncation (floor between multiply and add) breaks the
affine form the MXU wants, so the kernel deliberately targets the VPU:
one elementwise product tile, two masked reductions, a scalar correction.

`interpret=True` is mandatory on CPU — real TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _axsum_kernel(x_ref, w_ref, b_ref, s_ref, o_ref):
    """One batch-tile of the AxSum layer.

    x_ref: [Bt, Din]  integer-valued activations (unsigned domain)
    w_ref: [Din, Dout] integer-valued signed coefficients
    b_ref: [1, Dout]  integer-valued signed biases
    s_ref: [Din, Dout] truncation shifts (s = n-k for pruned products, else 0)
    o_ref: [Bt, Dout] pre-activation output S'
    """
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...][0]
    s = s_ref[...]

    absw = jnp.abs(w)
    # Bespoke multipliers: p_ij = a_i * |w_ij|   [Bt, Din, Dout]
    p = x[:, :, None] * absw[None, :, :]
    # AxSum truncation: drop the low s bits of each product.
    pow2 = jnp.exp2(s)[None, :, :]
    t = jnp.floor(p / pow2) * pow2
    # Split-sign adder trees.
    pos = (w >= 0).astype(x.dtype)[None, :, :]
    sp = jnp.sum(t * pos, axis=1) + jnp.maximum(b, 0.0)[None, :]
    sn = jnp.sum(t * (1.0 - pos), axis=1) + jnp.maximum(-b, 0.0)[None, :]
    # 1's-complement negation of the negative tree: ~Sn = -Sn - 1,
    # omitted entirely when the neuron has no negative contribution.
    has_neg = jnp.logical_or(jnp.any(w < 0, axis=0), b < 0)
    corr = has_neg.astype(x.dtype)[None, :]
    o_ref[...] = sp - sn - corr


def axsum_layer(x, w, b, s, *, block_b=64, interpret=True):
    """AxSum layer via pallas_call, batch-tiled.

    x [B, Din], w [Din, Dout], b [Dout], s [Din, Dout] -> [B, Dout].
    B must be a multiple of block_b (the AOT artifacts use fixed batch
    sizes; callers pad).
    """
    bsz, din = x.shape
    dout = w.shape[1]
    if bsz % block_b != 0:
        raise ValueError(f"batch {bsz} not a multiple of block_b {block_b}")
    b2 = b.reshape(1, dout)
    grid = (bsz // block_b,)
    return pl.pallas_call(
        _axsum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, din), lambda i: (i, 0)),
            pl.BlockSpec((din, dout), lambda i: (0, 0)),
            pl.BlockSpec((1, dout), lambda i: (0, 0)),
            pl.BlockSpec((din, dout), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, dout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, dout), x.dtype),
        interpret=interpret,
    )(x, w, b2, s)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def axsum_layer_jit(x, w, b, s, block_b=64, interpret=True):
    return axsum_layer(x, w, b, s, block_b=block_b, interpret=interpret)


def vmem_footprint_bytes(block_b, din, dout, dtype_bytes=4):
    """Static VMEM budget estimate for one grid step (DESIGN.md §HW-Adapt).

    Counts the resident input/output tiles plus the [Bt, Din, Dout]
    product intermediate the VPU materializes.
    """
    tiles = block_b * din + din * dout * 2 + dout + block_b * dout
    intermediate = block_b * din * dout
    return (tiles + intermediate) * dtype_bytes

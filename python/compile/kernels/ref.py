"""Pure-jnp (and pure-python integer) oracles for the AxSum neuron layer.

This file is the correctness contract shared by three implementations:

  1. the Pallas kernel in `kernels/axsum.py` (checked by pytest),
  2. the lowered HLO artifacts executed from Rust via PJRT,
  3. the bit-exact integer model in `rust/src/axsum/` (ground truth for DSE).

Semantics (paper Eq. (3)-(5), Fig. 4)
-------------------------------------
Inputs of a neuron are unsigned integers (4-bit primary inputs, or the
full-width ReLU output bus of the previous layer). Coefficients are signed
integers hardwired per multiplier. For each neuron j:

    p_ij   = a_i * |w_ij|                      (bespoke multiplier output)
    t_ij   = floor(p_ij / 2^s_ij) * 2^s_ij     (AxSum: keep k MSBs of the
                                                n_ij-bit product; s_ij =
                                                n_ij - k if G_ij <= G else 0)
    Sp_j   = sum_{w_ij >= 0} t_ij + max(b_j, 0)
    Sn_j   = sum_{w_ij <  0} t_ij + max(-b_j, 0)
    S'_j   = Sp_j + ~Sn_j = Sp_j - Sn_j - 1    (1's-complement negation)

If the neuron has no negative coefficient and a non-negative bias, the Sn
tree (and the -1 correction) is omitted entirely: S'_j = Sp_j.

All tensors are float32 holding exact small integers; products stay well
below 2^24 for every paper topology in practice (the Rust i64 model is the
bit-exact authority and tests cross-check the two on trained models).
"""

import jax.numpy as jnp
import numpy as np


def axsum_layer_ref(x, w, b, s):
    """Reference AxSum layer: x [B, Din], w [Din, Dout], b [Dout],
    s [Din, Dout] truncation shifts. Returns pre-activation [B, Dout]."""
    absw = jnp.abs(w)
    p = x[:, :, None] * absw[None, :, :]  # [B, Din, Dout]
    pow2 = jnp.exp2(s)[None, :, :]
    t = jnp.floor(p / pow2) * pow2
    pos = (w >= 0).astype(x.dtype)[None, :, :]
    sp = jnp.sum(t * pos, axis=1) + jnp.maximum(b, 0.0)[None, :]
    sn = jnp.sum(t * (1.0 - pos), axis=1) + jnp.maximum(-b, 0.0)[None, :]
    has_neg = jnp.logical_or(jnp.any(w < 0, axis=0), b < 0)
    corr = has_neg.astype(x.dtype)[None, :]
    return sp - sn - corr


def mlp_fwd_ref(x, w1, b1, s1, w2, b2, s2):
    """Two-layer AxSum MLP forward (integer domain), ReLU hidden."""
    h = jnp.maximum(axsum_layer_ref(x, w1, b1, s1), 0.0)
    return axsum_layer_ref(h, w2, b2, s2)


# ---------------------------------------------------------------------------
# Pure-python integer oracle (no jax) — mirrors rust/src/axsum exactly.
# ---------------------------------------------------------------------------

def axsum_neuron_int(a, w, bias, shifts):
    """Bit-exact integer AxSum for a single neuron.

    a: list[int] unsigned inputs; w: list[int] signed coefficients;
    shifts: list[int] per-product truncation shift. Returns S' (int).
    """
    sp = max(bias, 0)
    sn = max(-bias, 0)
    has_neg = bias < 0
    for ai, wi, si in zip(a, w, shifts):
        p = ai * abs(wi)
        t = (p >> si) << si
        if wi >= 0:
            sp += t
        else:
            sn += t
            has_neg = True
    has_neg = has_neg or any(wi < 0 for wi in w)
    return sp - sn - 1 if has_neg else sp


def axsum_layer_int(xs, w, b, s):
    """Integer AxSum layer over a batch. xs: [B][Din] ints, w: [Din][Dout],
    b: [Dout], s: [Din][Dout]. Returns [B][Dout] ints."""
    out = []
    din = len(w)
    dout = len(b)
    for row_in in xs:
        row = []
        for j in range(dout):
            wj = [w[i][j] for i in range(din)]
            sj = [s[i][j] for i in range(din)]
            row.append(axsum_neuron_int(row_in, wj, b[j], sj))
        out.append(row)
    return out


def product_bits(a_bits, w):
    """n_i = $size(|w|) + $size(a): width of the bespoke product."""
    wv = abs(int(w))
    if wv == 0:
        return 0
    return int(wv).bit_length() + a_bits


def np_int_layer(x, w, b, s):
    """Vectorized numpy int64 oracle (used by hypothesis tests)."""
    x = np.asarray(x, dtype=np.int64)
    w = np.asarray(w, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    s = np.asarray(s, dtype=np.int64)
    p = x[:, :, None] * np.abs(w)[None, :, :]
    t = (p >> s[None, :, :]) << s[None, :, :]
    pos = w >= 0
    sp = (t * pos[None, :, :]).sum(axis=1) + np.maximum(b, 0)[None, :]
    sn = (t * (~pos)[None, :, :]).sum(axis=1) + np.maximum(-b, 0)[None, :]
    has_neg = np.logical_or((w < 0).any(axis=0), b < 0)
    return sp - sn - has_neg.astype(np.int64)[None, :]

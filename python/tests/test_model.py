"""L2 correctness: quantized forward, VC projection, and the train step."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.model import (float_fwd, mlp_fwd_axsum, project_vc, train_step)
from compile.topologies import TOPOLOGIES, VC_MAX, W_MAX


def _vc(values):
    vc = np.zeros(VC_MAX, dtype=np.float32)
    mask = np.zeros(VC_MAX, dtype=np.float32)
    vc[: len(values)] = np.asarray(values, dtype=np.float32)
    mask[: len(values)] = 1.0
    return jnp.asarray(vc), jnp.asarray(mask)


def _rand_mlp(rng, din, hidden, dout):
    w1 = rng.integers(-40, 40, size=(din, hidden)).astype(np.float32)
    b1 = rng.normal(0, 10, size=(hidden,)).astype(np.float32)
    w2 = rng.integers(-40, 40, size=(hidden, dout)).astype(np.float32)
    b2 = rng.normal(0, 10, size=(dout,)).astype(np.float32)
    return w1, b1, w2, b2


@pytest.mark.parametrize("key,name,din,hidden,dout",
                         [(t[0], t[1], t[2], t[3], t[4]) for t in TOPOLOGIES])
def test_fwd_shapes_all_topologies(key, name, din, hidden, dout):
    rng = np.random.default_rng(1)
    w1, b1, w2, b2 = _rand_mlp(rng, din, hidden, dout)
    x = rng.integers(0, 16, size=(64, din)).astype(np.float32)
    (o,) = mlp_fwd_axsum(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(b1),
                         jnp.zeros((din, hidden)), jnp.asarray(w2),
                         jnp.asarray(b2), jnp.zeros((hidden, dout)))
    assert o.shape == (64, dout)
    assert np.isfinite(np.asarray(o)).all()


def test_fwd_exact_mode_matches_float_when_all_positive():
    """shifts=0 + all-positive weights/biases => plain integer matmul."""
    rng = np.random.default_rng(2)
    din, hidden, dout = 6, 3, 2
    w1 = rng.integers(0, 30, size=(din, hidden)).astype(np.float32)
    b1 = rng.integers(0, 20, size=(hidden,)).astype(np.float32)
    w2 = rng.integers(0, 30, size=(hidden, dout)).astype(np.float32)
    b2 = rng.integers(0, 20, size=(dout,)).astype(np.float32)
    x = rng.integers(0, 16, size=(64, din)).astype(np.float32)
    (o,) = mlp_fwd_axsum(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(b1),
                         jnp.zeros((din, hidden)), jnp.asarray(w2),
                         jnp.asarray(b2), jnp.zeros((hidden, dout)))
    want = float_fwd(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(b1),
                     jnp.asarray(w2), jnp.asarray(b2))
    np.testing.assert_array_equal(np.asarray(o), np.asarray(want))


def test_fwd_argmax_invariant_under_ones_complement():
    """The 1's-complement -1 offset applies per-neuron; with mixed-sign
    weights the exact-mode (s=0) logits differ from the float model by at
    most 1 + propagated hidden offset; argmax on separated logits agrees."""
    rng = np.random.default_rng(3)
    din, hidden, dout = 8, 4, 3
    w1, b1, w2, b2 = _rand_mlp(rng, din, hidden, dout)
    x = rng.integers(0, 16, size=(128, din)).astype(np.float32)
    (o,) = mlp_fwd_axsum(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(b1),
                         jnp.zeros((din, hidden)), jnp.asarray(w2),
                         jnp.asarray(b2), jnp.zeros((hidden, dout)))
    f = np.asarray(float_fwd(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(b1),
                             jnp.asarray(w2), jnp.asarray(b2)))
    o = np.asarray(o)
    # bounded deviation: per-neuron at most (1 + sum|w2| * 1) in magnitude
    bound = 1 + np.abs(w2).sum(axis=0).max()
    assert np.max(np.abs(o - f)) <= bound
    margin = np.sort(f, axis=1)[:, -1] - np.sort(f, axis=1)[:, -2]
    sep = margin > 2 * bound
    if sep.any():
        np.testing.assert_array_equal(o[sep].argmax(1), f[sep].argmax(1))


def test_project_vc_basic():
    vc, mask = _vc([0, 1, 2, 4, 8, -1, -2, -4, -8])
    w = jnp.asarray(np.array([[0.4, 3.1, -2.9], [7.0, -0.6, 100.0]], dtype=np.float32))
    p = np.asarray(project_vc(w, vc, mask))
    np.testing.assert_array_equal(p, np.array([[0, 4, -2], [8, -1, 8]], dtype=np.float32))


def test_project_vc_ignores_masked_slots():
    vc, mask = _vc([0, 64])
    # slot beyond mask holds 1.0 (would be closest) but must be ignored
    vc = vc.at[2].set(1.0)
    w = jnp.asarray(np.array([1.2], dtype=np.float32))
    assert float(project_vc(w, vc, mask)[0]) == 0.0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_project_vc_idempotent(seed):
    rng = np.random.default_rng(seed)
    vals = sorted(set(rng.integers(-W_MAX, W_MAX + 1, size=12).tolist()))
    vc, mask = _vc(vals)
    w = jnp.asarray(rng.uniform(-W_MAX, W_MAX, size=(5, 4)).astype(np.float32))
    p1 = project_vc(w, vc, mask)
    p2 = project_vc(p1, vc, mask)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    assert set(np.asarray(p1).ravel().tolist()) <= set(float(v) for v in vals)


def _toy_problem(rng, din=6, hidden=3, dout=3, n=64):
    w1, b1, w2, b2 = _rand_mlp(rng, din, hidden, dout)
    x = rng.integers(0, 16, size=(n, din)).astype(np.float32)
    y = rng.integers(0, dout, size=(n,))
    y1h = np.eye(dout, dtype=np.float32)[y]
    return (jnp.asarray(w1), jnp.asarray(b1), jnp.asarray(w2), jnp.asarray(b2),
            jnp.asarray(x), jnp.asarray(y1h))


def test_train_step_projects_onto_vc():
    rng = np.random.default_rng(5)
    w1, b1, w2, b2, x, y1h = _toy_problem(rng)
    vc, mask = _vc([0, 1, 2, 4, 8, 16, 32, 64, -1, -2, -4, -8, -16, -32, -64])
    out = train_step(w1, b1, w2, b2, x, y1h, vc, mask,
                     jnp.float32(0.05), jnp.float32(1000.0))
    w1q, w2q = np.asarray(out[4]), np.asarray(out[5])
    allowed = {0, 1, 2, 4, 8, 16, 32, 64, -1, -2, -4, -8, -16, -32, -64}
    assert set(w1q.ravel().astype(int).tolist()) <= allowed
    assert set(w2q.ravel().astype(int).tolist()) <= allowed


def test_train_step_reduces_loss():
    rng = np.random.default_rng(6)
    w1, b1, w2, b2, x, y1h = _toy_problem(rng, n=64)
    vc, mask = _vc(list(range(-W_MAX, W_MAX + 1)))  # dense VC: plain QAT
    lr, temp = jnp.float32(2.0), jnp.float32(500.0)
    losses = []
    for _ in range(60):
        w1, b1, w2, b2, _w1q, _w2q, loss, _ch = train_step(
            w1, b1, w2, b2, x, y1h, vc, mask, lr, temp)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses[:3] + losses[-3:]


def test_train_step_changed_counter():
    rng = np.random.default_rng(7)
    w1, b1, w2, b2, x, y1h = _toy_problem(rng)
    vc, mask = _vc([0, 64, -64])
    # lr=0: nothing can change
    out = train_step(w1, b1, w2, b2, x, y1h, vc, mask,
                     jnp.float32(0.0), jnp.float32(1000.0))
    assert float(out[7]) == 0.0
    # huge lr: projections must move
    out = train_step(w1, b1, w2, b2, x, y1h, vc, mask,
                     jnp.float32(1e4), jnp.float32(1000.0))
    assert float(out[7]) > 0.0


def test_train_step_clamps_shadow_weights():
    rng = np.random.default_rng(8)
    w1, b1, w2, b2, x, y1h = _toy_problem(rng)
    vc, mask = _vc(list(range(-W_MAX, W_MAX + 1)))
    out = train_step(w1, b1, w2, b2, x, y1h, vc, mask,
                     jnp.float32(1e5), jnp.float32(10.0))
    assert np.abs(np.asarray(out[0])).max() <= W_MAX
    assert np.abs(np.asarray(out[2])).max() <= W_MAX

"""AOT lowering sanity: HLO text artifacts parse-ready for the Rust loader."""

import json

import numpy as np
import jax.numpy as jnp

from compile import aot
from compile.topologies import EVAL_BATCH, TRAIN_BATCH, VC_MAX, by_key


def test_smoke_hlo_text():
    text = aot.to_hlo_text(aot.lower_smoke())
    assert "ENTRY" in text and "HloModule" in text
    # the loader depends on tuple-rooted outputs (return_tuple=True)
    assert "tuple" in text


def test_fwd_hlo_lowering_one_topology():
    _, _, din, hidden, dout, _, _ = by_key("v2")
    text = aot.to_hlo_text(aot.lower_fwd(din, hidden, dout))
    assert "ENTRY" in text
    assert f"f32[{EVAL_BATCH},{din}]" in text  # x param shape survives
    assert f"f32[{din},{hidden}]" in text


def test_train_hlo_lowering_one_topology():
    _, _, din, hidden, dout, _, _ = by_key("v2")
    text = aot.to_hlo_text(aot.lower_train(din, hidden, dout))
    assert "ENTRY" in text
    assert f"f32[{TRAIN_BATCH},{din}]" in text
    assert f"f32[{VC_MAX}]" in text


def test_fwd_lowered_executes_like_eager():
    """Round-trip the lowered fwd through jax's own compile+run: the
    artifact semantics equal the eager pallas path."""
    from compile.model import mlp_fwd_axsum

    _, _, din, hidden, dout, _, _ = by_key("ma")
    lowered = aot.lower_fwd(din, hidden, dout)
    compiled = lowered.compile()
    rng = np.random.default_rng(0)
    x = rng.integers(0, 16, size=(EVAL_BATCH, din)).astype(np.float32)
    w1 = rng.integers(-64, 64, size=(din, hidden)).astype(np.float32)
    b1 = rng.integers(-20, 20, size=(hidden,)).astype(np.float32)
    s1 = rng.integers(0, 4, size=(din, hidden)).astype(np.float32)
    w2 = rng.integers(-64, 64, size=(hidden, dout)).astype(np.float32)
    b2 = rng.integers(-20, 20, size=(dout,)).astype(np.float32)
    s2 = rng.integers(0, 4, size=(hidden, dout)).astype(np.float32)
    args = [jnp.asarray(a) for a in (x, w1, b1, s1, w2, b2, s2)]
    (got,) = compiled(*args)
    (want,) = mlp_fwd_axsum(*args)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_artifact_index_roundtrip(tmp_path):
    """aot.main writes a loadable index (run on a single tiny topology)."""
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out", str(tmp_path), "--only", "ma"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    idx = json.loads((tmp_path / "topologies.json").read_text())
    assert idx["eval_batch"] == EVAL_BATCH
    assert idx["topologies"][0]["key"] == "ma"
    assert (tmp_path / "fwd_ma.hlo.txt").exists()
    assert (tmp_path / "train_ma.hlo.txt").exists()
    assert (tmp_path / "smoke.hlo.txt").exists()

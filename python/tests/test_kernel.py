"""L1 correctness: Pallas AxSum kernel vs the pure-jnp and integer oracles.

This is the CORE correctness signal for the compute hot-spot: the same
semantics are relied on by the HLO artifacts (Rust eval path) and mirrored
bit-exactly by rust/src/axsum.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.axsum import axsum_layer, vmem_footprint_bytes
from compile.kernels.ref import (axsum_layer_int, axsum_layer_ref,
                                 np_int_layer, product_bits)
from compile.topologies import A_MAX, TOPOLOGIES, W_MAX


def _rand_case(rng, b, din, dout, a_max=A_MAX, w_max=W_MAX, max_shift=6):
    x = rng.integers(0, a_max + 1, size=(b, din)).astype(np.float32)
    w = rng.integers(-w_max - 1, w_max + 1, size=(din, dout)).astype(np.float32)
    bias = rng.integers(-200, 200, size=(dout,)).astype(np.float32)
    s = rng.integers(0, max_shift + 1, size=(din, dout)).astype(np.float32)
    return x, w, bias, s


@pytest.mark.parametrize("b,din,dout", [(64, 4, 3), (128, 11, 7), (64, 21, 3), (256, 16, 10)])
def test_kernel_matches_ref(b, din, dout):
    rng = np.random.default_rng(b * 1000 + din * 10 + dout)
    x, w, bias, s = _rand_case(rng, b, din, dout)
    got = axsum_layer(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), jnp.asarray(s))
    want = axsum_layer_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), jnp.asarray(s))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,din,dout", [(64, 5, 2), (64, 9, 3)])
def test_kernel_matches_integer_oracle(b, din, dout):
    rng = np.random.default_rng(7)
    x, w, bias, s = _rand_case(rng, b, din, dout)
    got = np.asarray(
        axsum_layer(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), jnp.asarray(s))
    ).astype(np.int64)
    want = np_int_layer(x, w, bias, s)
    np.testing.assert_array_equal(got, want)


def test_zero_shift_is_exact_weighted_sum_when_no_negatives():
    rng = np.random.default_rng(3)
    x = rng.integers(0, 16, size=(64, 6)).astype(np.float32)
    w = rng.integers(0, 128, size=(6, 4)).astype(np.float32)
    bias = rng.integers(0, 100, size=(4,)).astype(np.float32)
    s = np.zeros((6, 4), dtype=np.float32)
    got = np.asarray(axsum_layer(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), jnp.asarray(s)))
    want = x @ w + bias[None, :]
    # no negative coefficients -> no 1's-complement correction, fully exact
    np.testing.assert_array_equal(got, want)


def test_ones_complement_offset_with_negatives():
    # single neuron, one negative coefficient: S' = Sp - Sn - 1
    x = np.array([[3.0, 5.0]], dtype=np.float32)
    w = np.array([[2.0], [-4.0]], dtype=np.float32)
    bias = np.array([0.0], dtype=np.float32)
    s = np.zeros((2, 1), dtype=np.float32)
    got = np.asarray(axsum_layer(jnp.asarray(np.repeat(x, 64, 0)), jnp.asarray(w),
                                 jnp.asarray(bias), jnp.asarray(s)))[0, 0]
    assert got == 3 * 2 - 5 * 4 - 1


def test_negative_bias_triggers_correction():
    x = np.zeros((64, 2), dtype=np.float32)
    w = np.ones((2, 1), dtype=np.float32)
    bias = np.array([-7.0], dtype=np.float32)
    s = np.zeros((2, 1), dtype=np.float32)
    got = np.asarray(axsum_layer(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), jnp.asarray(s)))[0, 0]
    assert got == -(7) - 1  # 0 - Sn(=|b|) - 1


def test_truncation_drops_low_bits_only():
    # p = 5*3 = 15 (0b1111); shift 2 -> keep 0b11xx = 12
    x = np.full((64, 1), 5.0, dtype=np.float32)
    w = np.array([[3.0]], dtype=np.float32)
    bias = np.array([0.0], dtype=np.float32)
    s = np.array([[2.0]], dtype=np.float32)
    got = np.asarray(axsum_layer(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), jnp.asarray(s)))[0, 0]
    assert got == 12.0


@settings(max_examples=40, deadline=None)
@given(
    din=st.integers(1, 12),
    dout=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
    max_shift=st.integers(0, 10),
)
def test_hypothesis_kernel_vs_numpy_int(din, dout, seed, max_shift):
    """Property sweep over layer shapes / shift ranges (hypothesis)."""
    rng = np.random.default_rng(seed)
    x, w, bias, s = _rand_case(rng, 64, din, dout, max_shift=max_shift)
    got = np.asarray(
        axsum_layer(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), jnp.asarray(s))
    ).astype(np.int64)
    want = np_int_layer(x, w, bias, s)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_python_int_oracle_agrees(seed):
    rng = np.random.default_rng(seed)
    x, w, bias, s = _rand_case(rng, 8, 5, 3)
    want = np_int_layer(x, w, bias, s)
    got = axsum_layer_int(
        x.astype(int).tolist(), w.astype(int).tolist(),
        bias.astype(int).tolist(), s.astype(int).tolist(),
    )
    np.testing.assert_array_equal(np.array(got), want)


def test_batch_tiling_invariance():
    """Result must not depend on the pallas grid tiling."""
    rng = np.random.default_rng(11)
    x, w, bias, s = _rand_case(rng, 128, 7, 3)
    a = axsum_layer(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), jnp.asarray(s), block_b=64)
    b = axsum_layer(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), jnp.asarray(s), block_b=128)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bad_batch_raises():
    with pytest.raises(ValueError):
        axsum_layer(jnp.zeros((65, 3)), jnp.zeros((3, 2)), jnp.zeros((2,)), jnp.zeros((3, 2)))


def test_product_bits():
    assert product_bits(4, 7) == 7      # paper's example: w=+/-7, 4-bit input
    assert product_bits(4, 1) == 5
    assert product_bits(4, 0) == 0
    assert product_bits(4, -128) == 12


def test_vmem_budget_all_topologies():
    """DESIGN.md §Hardware-Adaptation: tile footprint <= 4 MB VMEM-class
    budget for every paper topology at block_b=64 (weights+shifts resident)."""
    for _key, _n, din, hidden, dout, _m, _a in TOPOLOGIES:
        for (a, b) in ((din, hidden), (hidden, dout)):
            assert vmem_footprint_bytes(64, a, b) < 4 << 20
